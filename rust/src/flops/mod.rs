//! FLOP accounting.
//!
//! The paper reports *FLOP compression rates*: the ratio of average FLOPs
//! needed to decode a 512-token sequence by the adapted model vs. the dense
//! model (§5.1 "Performance Evaluations", Appendix A.3 Tab. 4). This module
//! implements that accounting exactly, so Tab. 4's Total/MLP/QKV breakdown
//! and all "x% compression" labels in the tables/figures are computed, not
//! estimated.
//!
//! Conventions: a dense linear `o×i` costs `2·o·i` FLOPs per token
//! (multiply + add). Adaptive components report *expected* FLOPs under the
//! calibration distribution (the paper's constraint `E_x[‖m(x)‖₀] = r`).
//!
//! The analytic formulas here are the *prediction*; [`measured`] holds the
//! kernel-level counters that record what the engine actually executed, and
//! the `serving_flops` bench plus the conservation tests pin the two
//! against each other.

pub mod measured;

/// FLOPs of a dense linear layer per token.
pub fn linear(o: usize, i: usize) -> f64 {
    2.0 * o as f64 * i as f64
}

/// Per-token FLOPs of one adapted linear layer, decomposed.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LinearFlops {
    /// FLOPs spent computing the masker/router (e.g. `Bx` for the B-masker,
    /// the small MLP for sigmoid maskers, scoring for neuron thresholding).
    pub masker: f64,
    /// Expected FLOPs of the masked main computation (`A(m ⊙ Bx)` etc.).
    pub main: f64,
}

impl LinearFlops {
    pub fn dense(o: usize, i: usize) -> Self {
        Self { masker: 0.0, main: linear(o, i) }
    }

    pub fn total(&self) -> f64 {
        self.masker + self.main
    }
}

/// Rank adapter (paper §4.1): `A(m(x) ⊙ Bx)` with `B: d×i`, `A: o×d`,
/// expected active rank `r_avg`.
/// Masker = full `Bx` (2·d·i) + thresholding (d);
/// main = masked A-side contraction (2·o·r_avg).
pub fn rank_adapter(o: usize, i: usize, d: usize, r_avg: f64) -> LinearFlops {
    LinearFlops {
        masker: 2.0 * d as f64 * i as f64 + d as f64,
        main: 2.0 * o as f64 * r_avg,
    }
}

/// MLP-sigmoid masker (paper §4.1): `σ(C D x)`, `D: r'×i`, `C: d×r'`.
pub fn mlp_sigmoid_masker(i: usize, r_inner: usize, d: usize) -> f64 {
    2.0 * r_inner as f64 * i as f64 + 2.0 * d as f64 * r_inner as f64 + 2.0 * d as f64
}

/// Neuron-thresholding adapter on a down-projection (paper eqn. 12):
/// score `|x_i|·‖W_{:,i}‖` (2·h) then masked product (2·o·r_avg).
pub fn neuron_threshold(o: usize, h: usize, r_avg: f64) -> LinearFlops {
    LinearFlops { masker: 2.0 * h as f64, main: 2.0 * o as f64 * r_avg }
}

/// CATS-adapted SwiGLU MLP (§2): full Gate, threshold on |SiLU(gate)|, then
/// Up and Down only on active neurons.
pub fn cats_mlp(d: usize, h: usize, r_avg: f64) -> MlpFlops {
    MlpFlops {
        gate: LinearFlops::dense(h, d),
        up: LinearFlops { masker: 0.0, main: 2.0 * r_avg * d as f64 },
        down: LinearFlops { masker: h as f64, main: 2.0 * d as f64 * r_avg },
        act: h as f64, // SiLU on the full gate output
    }
}

/// Per-token FLOPs of an MLP block, by component.
#[derive(Clone, Copy, Debug, Default)]
pub struct MlpFlops {
    pub up: LinearFlops,
    pub gate: LinearFlops,
    pub down: LinearFlops,
    /// Activation + elementwise glue.
    pub act: f64,
}

impl MlpFlops {
    pub fn dense_swiglu(d: usize, h: usize) -> Self {
        Self {
            up: LinearFlops::dense(h, d),
            gate: LinearFlops::dense(h, d),
            down: LinearFlops::dense(d, h),
            act: 2.0 * h as f64,
        }
    }

    pub fn dense_gelu(d: usize, h: usize) -> Self {
        Self {
            up: LinearFlops::dense(h, d),
            gate: LinearFlops::default(), // no gate path
            down: LinearFlops::dense(d, h),
            act: h as f64,
        }
    }

    pub fn total(&self) -> f64 {
        self.up.total() + self.gate.total() + self.down.total() + self.act
    }
}

/// Per-token FLOPs of an attention block at a given KV context length.
#[derive(Clone, Copy, Debug, Default)]
pub struct AttnFlops {
    pub qkv: LinearFlops,
    pub out_proj: f64,
    /// Scores + weighted sum, grows with context.
    pub attention: f64,
    pub rope: f64,
}

impl AttnFlops {
    pub fn dense(d: usize, ctx: usize) -> Self {
        Self {
            qkv: LinearFlops::dense(3 * d, d),
            out_proj: linear(d, d),
            attention: 4.0 * d as f64 * ctx as f64,
            rope: 4.0 * d as f64,
        }
    }

    pub fn total(&self) -> f64 {
        self.qkv.total() + self.out_proj + self.attention + self.rope
    }
}

/// Whole-model per-token FLOPs at a context length.
#[derive(Clone, Copy, Debug, Default)]
pub struct BlockFlops {
    pub attn: AttnFlops,
    pub mlp: MlpFlops,
    pub norms: f64,
}

/// Model-level FLOP summary for decoding a sequence.
#[derive(Clone, Copy, Debug, Default)]
pub struct DecodeFlops {
    pub total: f64,
    pub mlp: f64,
    pub qkv: f64,
    pub attn_other: f64,
    pub lm_head: f64,
}

impl DecodeFlops {
    /// FLOP compression rate vs. a dense counterpart: `1 - self/dense`.
    pub fn compression_vs(&self, dense: &DecodeFlops) -> f64 {
        1.0 - self.total / dense.total
    }

    pub fn mlp_compression_vs(&self, dense: &DecodeFlops) -> f64 {
        1.0 - self.mlp / dense.mlp
    }

    pub fn qkv_compression_vs(&self, dense: &DecodeFlops) -> f64 {
        if dense.qkv == 0.0 {
            0.0
        } else {
            1.0 - self.qkv / dense.qkv
        }
    }
}

/// Accumulate per-token block FLOPs over decoding `seq_len` tokens
/// (context grows 1..seq_len), matching the paper's "average FLOPs to
/// decode 512-token sequences".
pub fn decode_flops(
    per_block: impl Fn(usize) -> BlockFlops, // ctx → per-layer flops
    n_layers: usize,
    d: usize,
    vocab: usize,
    seq_len: usize,
) -> DecodeFlops {
    let mut out = DecodeFlops::default();
    for ctx in 1..=seq_len {
        let b = per_block(ctx);
        out.mlp += n_layers as f64 * b.mlp.total();
        out.qkv += n_layers as f64 * b.attn.qkv.total();
        out.attn_other +=
            n_layers as f64 * (b.attn.out_proj + b.attn.attention + b.attn.rope + b.norms);
        out.lm_head += linear(vocab, d);
    }
    out.total = out.mlp + out.qkv + out.attn_other + out.lm_head;
    // Average per decoded token, like the paper's per-token accounting.
    let n = seq_len as f64;
    out.total /= n;
    out.mlp /= n;
    out.qkv /= n;
    out.attn_other /= n;
    out.lm_head /= n;
    out
}

/// Undivided sibling of [`decode_flops`]: the *total* FLOPs to decode
/// `seq_len` tokens (context grows 1..=seq_len), without the per-token
/// averaging — the quantity the measured counters accumulate over a full
/// sequence, so conservation tests can compare exactly.
pub fn decode_flops_sum(
    per_block: impl Fn(usize) -> BlockFlops, // ctx → per-layer flops
    n_layers: usize,
    d: usize,
    vocab: usize,
    seq_len: usize,
) -> f64 {
    let mut total = 0.0;
    for ctx in 1..=seq_len {
        let b = per_block(ctx);
        total += n_layers as f64
            * (b.mlp.total()
                + b.attn.qkv.total()
                + b.attn.out_proj
                + b.attn.attention
                + b.attn.rope
                + b.norms);
        total += linear(vocab, d);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_linear_flops() {
        assert_eq!(linear(4, 8), 64.0);
        assert_eq!(LinearFlops::dense(4, 8).total(), 64.0);
    }

    #[test]
    fn rank_adapter_flops_balance() {
        // d=16 ranks kept statically, r_avg=4 active on average, o=32, i=8.
        let f = rank_adapter(32, 8, 16, 4.0);
        assert_eq!(f.masker, 2.0 * 16.0 * 8.0 + 16.0);
        assert_eq!(f.main, 2.0 * 32.0 * 4.0);
    }

    #[test]
    fn cats_allocates_most_flops_to_gate_at_high_compression() {
        // The paper's critique (§2): at high compression CATS still pays the
        // full Gate projection. Verify gate dominates at small r_avg.
        let f = cats_mlp(256, 704, 70.0);
        assert!(f.gate.total() > f.up.total() * 3.0);
        assert!(f.gate.total() > f.down.total() * 3.0);
    }

    #[test]
    fn swiglu_dense_mlp_total() {
        let f = MlpFlops::dense_swiglu(256, 704);
        let expect = 2.0 * (2.0 * 704.0 * 256.0) + 2.0 * 256.0 * 704.0 + 2.0 * 704.0;
        assert_eq!(f.total(), expect);
    }

    #[test]
    fn compression_rate_sanity() {
        let dense = DecodeFlops { total: 100.0, mlp: 60.0, qkv: 20.0, ..Default::default() };
        let adapted = DecodeFlops { total: 58.0, mlp: 30.0, qkv: 10.0, ..Default::default() };
        assert!((adapted.compression_vs(&dense) - 0.42).abs() < 1e-12);
        assert!((adapted.mlp_compression_vs(&dense) - 0.5).abs() < 1e-12);
        assert!((adapted.qkv_compression_vs(&dense) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn decode_flops_sum_is_undivided_average() {
        let d = 32;
        let per_block = |ctx: usize| BlockFlops {
            attn: AttnFlops::dense(d, ctx),
            mlp: MlpFlops::dense_swiglu(d, 4 * d),
            norms: 0.0,
        };
        let avg = decode_flops(per_block, 3, d, 50, 24);
        let sum = decode_flops_sum(per_block, 3, d, 50, 24);
        assert!((sum - avg.total * 24.0).abs() < 1e-6 * sum);
    }

    #[test]
    fn decode_flops_attention_grows_with_context() {
        let d = 64;
        let short = decode_flops(|ctx| BlockFlops {
            attn: AttnFlops::dense(d, ctx),
            mlp: MlpFlops::dense_swiglu(d, 4 * d),
            norms: 0.0,
        }, 2, d, 100, 16);
        let long = decode_flops(|ctx| BlockFlops {
            attn: AttnFlops::dense(d, ctx),
            mlp: MlpFlops::dense_swiglu(d, 4 * d),
            norms: 0.0,
        }, 2, d, 100, 128);
        // Per-token MLP cost is context-independent; attention is not.
        assert!((short.mlp - long.mlp).abs() < 1e-6);
        assert!(long.attn_other > short.attn_other);
    }
}
