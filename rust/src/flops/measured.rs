//! Measured compute accounting: cheap thread-local multiply-add and
//! bytes-touched counters incremented at the kernel *composition* call
//! sites (GEMV/GEMM entry points, masked kernels, attention, activations)
//! — one relaxed add per kernel call, never per element — so the engine
//! can report the FLOPs it actually executed next to the analytic
//! estimates in [`crate::flops`].
//!
//! Design contract (DESIGN.md §2i):
//!
//! * **Zero compute-path branches.** Counting never changes what a kernel
//!   computes — every bitwise determinism pin (§2a–§2h) holds with
//!   counting on or off. The only per-call branch is one relaxed
//!   `AtomicBool` load.
//! * **Composition-level sites.** Counts are added where kernels are
//!   *composed* (`gemv_slices`, `gemv_batch` stripes, `gemm_rows_axpy`
//!   chunks, `gemm_packed` panels, the masked accumulators,
//!   `attention_over_*`, activations, adapter maskers), never inside the
//!   `Kernel` trait primitives — each executed multiply-add is counted
//!   exactly once regardless of backend or dispatch path.
//! * **FLOPs = 2 × multiply-adds**, the same convention as
//!   [`crate::flops::linear`]. Masked kernels count their *actual* active
//!   rows; dense kernels count nominal `2·m·k·n` (the exact-zero skip in
//!   the accumulation loops is an implementation detail, not a FLOP
//!   saving the schedule planned). Norms, residual adds, embedding
//!   lookups and the sampler are not counted, matching the analytic
//!   formulas at `norms = 0`.
//! * **Bytes are nominal touched bytes** — 4 × (elements read + written)
//!   per call: an arithmetic-intensity denominator for bandwidth
//!   accounting, not a cache-traffic measurement.
//!
//! Counters are **process-global**: each thread owns a registered slot of
//! two relaxed `AtomicU64`s; [`snapshot`] folds dead threads' retired
//! totals plus every live slot under a registry lock (thread exit folds
//! the slot into the dead totals under the same lock, so no count is ever
//! lost or double-read). Per-layer attribution
//! ([`add_layer`]/[`layer_snapshot`]) is likewise process-global and
//! cumulative; with several engines in one process the totals aggregate
//! across them, so tests that assert exact counts serialize on a lock.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Cumulative measured totals. `flops` counts 2 × multiply-adds; `bytes`
/// counts nominal touched bytes (see module docs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Counts {
    pub flops: u64,
    pub bytes: u64,
}

impl Counts {
    /// Saturating element-wise `self − base` (running totals vs a
    /// baseline — same delta shape as `trace::PhaseTotals::delta_since`).
    pub fn delta_since(&self, base: &Counts) -> Counts {
        Counts {
            flops: self.flops.saturating_sub(base.flops),
            bytes: self.bytes.saturating_sub(base.bytes),
        }
    }

    pub fn is_zero(&self) -> bool {
        self.flops == 0 && self.bytes == 0
    }
}

impl std::ops::AddAssign for Counts {
    fn add_assign(&mut self, rhs: Counts) {
        self.flops = self.flops.saturating_add(rhs.flops);
        self.bytes = self.bytes.saturating_add(rhs.bytes);
    }
}

/// Measured compute split by engine phase, the compute-side sibling of
/// `trace::PhaseTotals`: batches keep running totals, sessions report
/// deltas upward into `Metrics`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FlopPhases {
    /// Prompt prefill / preemption-refeed rows.
    pub prefill: Counts,
    /// Plain generation rows.
    pub decode: Counts,
    /// Speculative verify rows (the drafted tail of a spec round).
    pub verify: Counts,
    /// Low-budget draft passes.
    pub draft: Counts,
}

impl FlopPhases {
    pub fn delta_since(&self, base: &FlopPhases) -> FlopPhases {
        FlopPhases {
            prefill: self.prefill.delta_since(&base.prefill),
            decode: self.decode.delta_since(&base.decode),
            verify: self.verify.delta_since(&base.verify),
            draft: self.draft.delta_since(&base.draft),
        }
    }

    pub fn is_zero(&self) -> bool {
        self.prefill.is_zero()
            && self.decode.is_zero()
            && self.verify.is_zero()
            && self.draft.is_zero()
    }

    /// Total measured compute across all phases.
    pub fn total(&self) -> Counts {
        Counts {
            flops: self.prefill.flops + self.decode.flops + self.verify.flops + self.draft.flops,
            bytes: self.prefill.bytes + self.decode.bytes + self.verify.bytes + self.draft.bytes,
        }
    }

    /// Attribute one full-budget engine pass's measured delta across the
    /// row kinds it served, proportionally by row count with the
    /// remainder going to the largest share — the same arithmetic
    /// attribution rule as `PhaseTotals::attribute_pass` (one pass is one
    /// fused matmul; the split is accounting, never a compute branch).
    pub fn attribute_pass(
        &mut self,
        delta: Counts,
        prefill_rows: u64,
        decode_rows: u64,
        verify_rows: u64,
    ) {
        let (pf, df, vf) = split_three(delta.flops, prefill_rows, decode_rows, verify_rows);
        let (pb, db, vb) = split_three(delta.bytes, prefill_rows, decode_rows, verify_rows);
        self.prefill.flops += pf;
        self.prefill.bytes += pb;
        self.decode.flops += df;
        self.decode.bytes += db;
        self.verify.flops += vf;
        self.verify.bytes += vb;
    }
}

/// Split `total` proportionally over three row counts; all-zero rows put
/// everything in the decode share; the integer remainder goes to the
/// largest share (verify beats decode on ties only when strictly larger,
/// mirroring `PhaseTotals`).
fn split_three(total: u64, prefill: u64, decode: u64, verify: u64) -> (u64, u64, u64) {
    let rows = prefill + decode + verify;
    if rows == 0 {
        return (0, total, 0);
    }
    let share = |r: u64| ((total as u128 * r as u128) / rows as u128) as u64;
    let (mut p, mut d, mut v) = (share(prefill), share(decode), share(verify));
    let rem = total - (p + d + v);
    if prefill >= decode && prefill >= verify {
        p += rem;
    } else if verify > decode {
        v += rem;
    } else {
        d += rem;
    }
    (p, d, v)
}

struct ThreadSlot {
    flops: AtomicU64,
    bytes: AtomicU64,
}

struct Registry {
    /// Live per-thread slots; each registered thread holds one `Arc`.
    slots: Mutex<Vec<Arc<ThreadSlot>>>,
    /// Totals folded in from threads that have exited.
    dead_flops: AtomicU64,
    dead_bytes: AtomicU64,
}

fn registry() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(|| Registry {
        slots: Mutex::new(Vec::new()),
        dead_flops: AtomicU64::new(0),
        dead_bytes: AtomicU64::new(0),
    })
}

fn lock_slots() -> std::sync::MutexGuard<'static, Vec<Arc<ThreadSlot>>> {
    // Counter state is monotone totals — safe to keep using after a
    // panicking holder (same recovery stance as `trace::lock_recover`).
    match registry().slots.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

/// On thread exit: remove the slot from the registry and fold its totals
/// into the dead counters under the registry lock, so a concurrent
/// [`snapshot`] sees the slot exactly once (live xor dead).
struct SlotHandle(Arc<ThreadSlot>);

impl Drop for SlotHandle {
    fn drop(&mut self) {
        let reg = registry();
        let mut slots = lock_slots();
        slots.retain(|s| !Arc::ptr_eq(s, &self.0));
        reg.dead_flops.fetch_add(self.0.flops.load(Ordering::Relaxed), Ordering::Relaxed);
        reg.dead_bytes.fetch_add(self.0.bytes.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

thread_local! {
    static SLOT: SlotHandle = {
        let slot = Arc::new(ThreadSlot { flops: AtomicU64::new(0), bytes: AtomicU64::new(0) });
        lock_slots().push(Arc::clone(&slot));
        SlotHandle(slot)
    };
}

/// Global counting switch (default on). Turning it off skips the counter
/// adds and the per-layer snapshots — it never alters what any kernel
/// computes; it exists so the overhead bench can A/B the counters
/// themselves.
static ENABLED: AtomicBool = AtomicBool::new(true);

pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Record one kernel call's nominal work: `flops` = 2 × multiply-adds,
/// `bytes` = 4 × (elements read + written). One relaxed add per counter
/// on the calling thread's slot.
#[inline]
pub fn add(flops: u64, bytes: u64) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    SLOT.with(|s| {
        s.0.flops.fetch_add(flops, Ordering::Relaxed);
        s.0.bytes.fetch_add(bytes, Ordering::Relaxed);
    });
}

/// Process-wide cumulative totals: dead threads' folded totals plus every
/// live slot. Exact with respect to completed parallel regions — the
/// pool's region-completion synchronization orders worker adds before the
/// caller's read.
pub fn snapshot() -> Counts {
    let reg = registry();
    let slots = lock_slots();
    let mut c = Counts {
        flops: reg.dead_flops.load(Ordering::Relaxed),
        bytes: reg.dead_bytes.load(Ordering::Relaxed),
    };
    for s in slots.iter() {
        c.flops += s.flops.load(Ordering::Relaxed);
        c.bytes += s.bytes.load(Ordering::Relaxed);
    }
    c
}

/// FLOPs-only snapshot — the cheap probe `decode_step_body` diffs around
/// each layer for per-layer attribution.
pub fn flops_now() -> u64 {
    let reg = registry();
    let slots = lock_slots();
    let mut f = reg.dead_flops.load(Ordering::Relaxed);
    for s in slots.iter() {
        f += s.flops.load(Ordering::Relaxed);
    }
    f
}

fn layer_flops() -> &'static Mutex<Vec<u64>> {
    static LAYERS: OnceLock<Mutex<Vec<u64>>> = OnceLock::new();
    LAYERS.get_or_init(|| Mutex::new(Vec::new()))
}

fn lock_layers() -> std::sync::MutexGuard<'static, Vec<u64>> {
    match layer_flops().lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

/// Credit `flops` to `layer` (the model's last slot + 1 is the lm-head
/// pseudo-layer). Called once per layer per engine pass by
/// `decode_step_body`; the vector grows to fit the largest layer seen.
pub fn add_layer(layer: usize, flops: u64) {
    if flops == 0 {
        return;
    }
    let mut v = lock_layers();
    if v.len() <= layer {
        v.resize(layer + 1, 0);
    }
    v[layer] = v[layer].saturating_add(flops);
}

/// Cumulative per-layer measured FLOPs, index = layer (last entry = the
/// lm-head pseudo-layer). Empty until the first counted pass.
pub fn layer_snapshot() -> Vec<u64> {
    lock_layers().clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_and_split_arithmetic() {
        let a = Counts { flops: 10, bytes: 100 };
        let b = Counts { flops: 4, bytes: 40 };
        assert_eq!(b.delta_since(&a), Counts::default(), "saturates below zero");
        assert_eq!(a.delta_since(&b), Counts { flops: 6, bytes: 60 });

        // Shares sum exactly to the total, remainder to the largest.
        let (p, d, v) = split_three(10, 1, 1, 1);
        assert_eq!(p + d + v, 10);
        assert_eq!(p, 4, "remainder lands on prefill when it ties for largest");
        assert_eq!(split_three(7, 0, 0, 0), (0, 7, 0), "no rows → decode");
        let (p, d, v) = split_three(100, 0, 1, 3);
        assert_eq!((p, d, v), (0, 25, 75));
    }

    #[test]
    fn attribute_pass_accumulates_by_row_kind() {
        let mut f = FlopPhases::default();
        f.attribute_pass(Counts { flops: 90, bytes: 9 }, 1, 1, 1);
        assert_eq!(f.prefill.flops, 30);
        assert_eq!(f.decode.flops, 30);
        assert_eq!(f.verify.flops, 30);
        assert_eq!(f.total().flops, 90);
        assert_eq!(f.total().bytes, 9);
        f.draft.flops += 10;
        assert_eq!(f.total().flops, 100);
        let base = FlopPhases::default();
        assert_eq!(f.delta_since(&base), f);
        assert!(f.delta_since(&f).is_zero());
    }

    // ONE lock shared by every test that mutates the global switch or
    // asserts on global deltas — separate locks would let `set_enabled`
    // race the fold test's adds. Exact-count assertions still can't run
    // here: other tests in this binary drive kernels concurrently, so
    // global deltas are lower bounds (the exact conservation laws live in
    // `tests/test_measured_flops.rs`, a binary that serializes fully).
    static GLOBAL_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn thread_slots_fold_without_losing_counts() {
        let _g = GLOBAL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        let before = snapshot();
        add(5, 50);
        let spawned: Vec<_> = (0..4)
            .map(|_| std::thread::spawn(|| add(100, 1000)))
            .collect();
        for h in spawned {
            h.join().unwrap();
        }
        let d = snapshot().delta_since(&before);
        assert!(d.flops >= 405, "dead-thread folds lost adds: {}", d.flops);
        assert!(d.bytes >= 4050, "dead-thread folds lost bytes: {}", d.bytes);
    }

    #[test]
    fn layer_vector_grows_and_accumulates() {
        let before = layer_snapshot();
        let at = |v: &[u64], i: usize| v.get(i).copied().unwrap_or(0);
        add_layer(2, 7);
        add_layer(0, 3);
        add_layer(2, 1);
        let after = layer_snapshot();
        assert!(after.len() >= 3);
        assert!(at(&after, 0) - at(&before, 0) >= 3);
        assert!(at(&after, 2) - at(&before, 2) >= 8);
    }

    #[test]
    fn disabled_counters_stand_still() {
        let _g = GLOBAL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        set_enabled(false);
        let before = SLOT.with(|s| s.0.flops.load(Ordering::Relaxed));
        add(1_000, 1_000);
        let after = SLOT.with(|s| s.0.flops.load(Ordering::Relaxed));
        set_enabled(true);
        // This thread's own slot is immune to other tests' adds.
        assert_eq!(after, before, "disabled adds must not count");
    }
}
