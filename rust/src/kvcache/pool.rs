//! Ref-counted fixed-size block pool + per-sequence paged KV cache view.
//!
//! One **logical block** spans all layers: block `b` owns token rows
//! `[b·bs, (b+1)·bs)` of every layer's pool-wide K and V buffers. A
//! sequence's cache is just a chain of block ids plus a token count; block
//! contents are written once per (layer, position) during decode and read
//! by the block-strided attention kernel
//! ([`crate::tensor::attention_over_paged`]).
//!
//! Sharing rules (DESIGN.md §2b):
//! * Blocks are ref-counted. The prefix trie and any number of sequences
//!   may hold the same block; only a block with refcount 1 is writable.
//! * All appends go to the position `len`, i.e. into the chain's *last*
//!   block. Shared **full** blocks are therefore never written again; a
//!   shared *partial* tail block (created by [`PagedKvCache::fork`]) is
//!   **copied on the first divergent append** (COW), so forks never observe
//!   each other's tokens.

use crate::model::ModelConfig;
use crate::tensor::Mat;

use super::CacheError;

/// Pool of fixed-size KV blocks, one K and one V buffer per layer.
pub struct BlockPool {
    block_size: usize,
    n_blocks: usize,
    n_layers: usize,
    /// Per layer: `[n_blocks * block_size, d_model]` key rows.
    k: Vec<Mat>,
    /// Per layer: `[n_blocks * block_size, d_model]` value rows.
    v: Vec<Mat>,
    ref_counts: Vec<u32>,
    /// LIFO free list (hot blocks are reused first).
    free: Vec<usize>,
    peak_in_use: usize,
}

impl BlockPool {
    /// Pool with `n_blocks` blocks of `block_size` token rows each, shaped
    /// for `cfg` (one K + one V row of `d_model` per layer per token).
    pub fn new(cfg: &ModelConfig, block_size: usize, n_blocks: usize) -> Self {
        assert!(block_size > 0, "block_size must be positive");
        assert!(n_blocks > 0, "pool needs at least one block");
        let rows = n_blocks * block_size;
        Self {
            block_size,
            n_blocks,
            n_layers: cfg.n_layers,
            k: (0..cfg.n_layers).map(|_| Mat::zeros(rows, cfg.d_model)).collect(),
            v: (0..cfg.n_layers).map(|_| Mat::zeros(rows, cfg.d_model)).collect(),
            ref_counts: vec![0; n_blocks],
            // LIFO: block 0 pops first.
            free: (0..n_blocks).rev().collect(),
            peak_in_use: 0,
        }
    }

    /// Blocks needed to hold `tokens` token rows.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn n_blocks(&self) -> usize {
        self.n_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn blocks_in_use(&self) -> usize {
        self.n_blocks - self.free.len()
    }

    pub fn blocks_peak(&self) -> usize {
        self.peak_in_use
    }

    pub fn ref_count(&self, block: usize) -> u32 {
        self.ref_counts[block]
    }

    /// Pool-wide K buffer of one layer (block-strided rows).
    #[inline]
    pub fn layer_k(&self, layer: usize) -> &Mat {
        &self.k[layer]
    }

    /// Pool-wide V buffer of one layer (block-strided rows).
    #[inline]
    pub fn layer_v(&self, layer: usize) -> &Mat {
        &self.v[layer]
    }

    /// Allocate one block (refcount 1), or `None` when the pool is empty.
    pub fn alloc(&mut self) -> Option<usize> {
        let b = self.free.pop()?;
        debug_assert_eq!(self.ref_counts[b], 0, "free-list block had live refs");
        self.ref_counts[b] = 1;
        self.peak_in_use = self.peak_in_use.max(self.blocks_in_use());
        Some(b)
    }

    /// Add one reference to a live block (prefix share / fork).
    pub fn retain(&mut self, block: usize) {
        assert!(self.ref_counts[block] > 0, "retain of a free block");
        self.ref_counts[block] += 1;
    }

    /// Drop one reference; the block returns to the free list at zero.
    pub fn release(&mut self, block: usize) {
        assert!(self.ref_counts[block] > 0, "release of a free block");
        self.ref_counts[block] -= 1;
        if self.ref_counts[block] == 0 {
            self.free.push(block);
        }
    }

    #[inline]
    fn row_index(&self, block: usize, slot: usize) -> usize {
        debug_assert!(slot < self.block_size);
        block * self.block_size + slot
    }

    /// Write one token's K/V rows for one layer into `(block, slot)`.
    pub fn write_kv(&mut self, layer: usize, block: usize, slot: usize, k: &[f32], v: &[f32]) {
        debug_assert!(self.ref_counts[block] == 1, "write to a shared/free block");
        let row = self.row_index(block, slot);
        self.k[layer].row_mut(row).copy_from_slice(k);
        self.v[layer].row_mut(row).copy_from_slice(v);
    }

    /// Copy the first `filled` slots of `src` into `dst` across all layers
    /// (the COW body).
    fn copy_block(&mut self, src: usize, dst: usize, filled: usize) {
        for layer in 0..self.n_layers {
            for slot in 0..filled {
                let s = self.row_index(src, slot);
                let d = self.row_index(dst, slot);
                let krow = self.k[layer].row(s).to_vec();
                self.k[layer].row_mut(d).copy_from_slice(&krow);
                let vrow = self.v[layer].row(s).to_vec();
                self.v[layer].row_mut(d).copy_from_slice(&vrow);
            }
        }
    }

    /// Internal consistency: every block is either free (refcount 0, on the
    /// free list exactly once) or live (refcount > 0, not on it).
    #[cfg(test)]
    pub fn check_invariants(&self) {
        assert!(self.free.len() <= self.n_blocks);
        let mut on_free = vec![false; self.n_blocks];
        for &b in &self.free {
            assert!(!on_free[b], "block {b} on free list twice");
            on_free[b] = true;
        }
        for b in 0..self.n_blocks {
            assert_eq!(
                self.ref_counts[b] == 0,
                on_free[b],
                "block {b}: refcount {} vs free-list {}",
                self.ref_counts[b],
                on_free[b]
            );
        }
        assert!(self.peak_in_use <= self.n_blocks);
        assert!(self.peak_in_use >= self.blocks_in_use());
    }
}

/// Per-sequence paged cache: a chain of pool blocks plus a token count.
/// Appending always targets position `len`; the chain grows a block at a
/// time and shared tail blocks are copied on first write (COW).
#[derive(Clone, Debug, Default)]
pub struct PagedKvCache {
    chain: Vec<usize>,
    len: usize,
}

impl PagedKvCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Adopt an already-retained prefix chain of `tokens` tokens (the trie
    /// hands out full blocks whose refcounts it has bumped for the caller).
    pub fn from_shared_prefix(chain: Vec<usize>, tokens: usize, block_size: usize) -> Self {
        debug_assert_eq!(chain.len() * block_size, tokens, "prefix must be full blocks");
        Self { chain, len: tokens }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn chain(&self) -> &[usize] {
        &self.chain
    }

    /// Blocks this cache currently holds a reference to.
    pub fn blocks_held(&self) -> usize {
        self.chain.len()
    }

    /// Make position `len` writable: allocate a fresh block when the chain
    /// is exactly full, and copy a shared tail block (COW) before the first
    /// divergent append. Idempotent once it has succeeded for a given `len`.
    pub fn prepare_append(&mut self, pool: &mut BlockPool) -> Result<(), CacheError> {
        let bs = pool.block_size();
        if self.len == self.chain.len() * bs {
            let b = pool.alloc().ok_or(CacheError::PoolExhausted {
                seq: 0,
                needed: 1,
                available: 0,
            })?;
            self.chain.push(b);
            return Ok(());
        }
        let idx = self.len / bs;
        debug_assert!(idx < self.chain.len());
        if pool.ref_count(self.chain[idx]) > 1 {
            // COW: the tail block is shared (fork); copy its filled prefix.
            let fresh = pool.alloc().ok_or(CacheError::PoolExhausted {
                seq: 0,
                needed: 1,
                available: 0,
            })?;
            pool.copy_block(self.chain[idx], fresh, self.len % bs);
            pool.release(self.chain[idx]);
            self.chain[idx] = fresh;
        }
        Ok(())
    }

    /// Make positions `len..len + n` writable in one go (block alloc +
    /// COW), for multi-token appends (speculative verify). Idempotent for
    /// already-prepared positions; on failure the chain is rolled back to
    /// exactly what `len` tokens need, so no blocks leak.
    pub fn prepare_append_n(&mut self, pool: &mut BlockPool, n: usize) -> Result<(), CacheError> {
        let base = self.len;
        for i in 0..n {
            self.len = base + i;
            if let Err(e) = self.prepare_append(pool) {
                self.len = base;
                let keep = base.div_ceil(pool.block_size());
                while self.chain.len() > keep {
                    let b = self.chain.pop().expect("checked length");
                    pool.release(b);
                }
                return Err(e);
            }
        }
        self.len = base;
        Ok(())
    }

    /// Write one layer's K/V rows for the token at position `len`.
    /// Requires a preceding successful [`PagedKvCache::prepare_append`].
    pub fn write_kv(&self, pool: &mut BlockPool, layer: usize, k: &[f32], v: &[f32]) {
        self.write_kv_at(pool, layer, self.len, k, v);
    }

    /// Write one layer's K/V rows at an explicit position in
    /// `len..len + n` previously made writable by
    /// [`PagedKvCache::prepare_append_n`] (multi-token appends write several
    /// positions before a single [`PagedKvCache::advance_n`] commit).
    pub fn write_kv_at(
        &self,
        pool: &mut BlockPool,
        layer: usize,
        pos: usize,
        k: &[f32],
        v: &[f32],
    ) {
        let bs = pool.block_size();
        let idx = pos / bs;
        pool.write_kv(layer, self.chain[idx], pos % bs, k, v);
    }

    /// Commit the append: position `len` is now part of the context.
    pub fn advance(&mut self) {
        self.len += 1;
    }

    /// Commit `n` prepared appends at once.
    pub fn advance_n(&mut self, n: usize) {
        self.len += n;
    }

    /// Roll the cache back to `len` tokens (`len <= self.len()`), releasing
    /// every whole block past the new end back to the pool. COW-aware by
    /// construction: only this cache's own references are dropped — a block
    /// shared with the prefix trie or a fork survives under the other
    /// holders' references, and the kept tail block is never written here
    /// (the next [`PagedKvCache::prepare_append`] copies it first if it is
    /// still shared). Callers must never truncate below a boundary whose
    /// blocks they have published (the prefix trie keeps its own refs, but
    /// the chain must keep covering every committed token).
    pub fn truncate(&mut self, pool: &mut BlockPool, len: usize) {
        assert!(len <= self.len, "truncate cannot extend ({} -> {len})", self.len);
        let keep = len.div_ceil(pool.block_size());
        while self.chain.len() > keep {
            let b = self.chain.pop().expect("checked length");
            pool.release(b);
        }
        self.len = len;
    }

    /// Share the whole cache (including a partial tail block) with a new
    /// handle; the next divergent append on either handle triggers COW.
    pub fn fork(&self, pool: &mut BlockPool) -> PagedKvCache {
        for &b in &self.chain {
            pool.retain(b);
        }
        self.clone()
    }

    /// Drop every block reference and reset to empty.
    pub fn release(&mut self, pool: &mut BlockPool) {
        for &b in &self.chain {
            pool.release(b);
        }
        self.chain.clear();
        self.len = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Arch, ModelConfig};
    use crate::util::rng::Xoshiro256;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            arch: Arch::SwiGlu,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_hidden: 16,
            vocab: 32,
            max_seq: 64,
            rope_theta: 10_000.0,
            norm_eps: 1e-5,
        }
    }

    #[test]
    fn alloc_release_roundtrip() {
        let mut pool = BlockPool::new(&cfg(), 4, 3);
        assert_eq!(pool.free_blocks(), 3);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        let c = pool.alloc().unwrap();
        assert!(pool.alloc().is_none(), "pool of 3 must refuse a 4th block");
        assert_eq!(pool.blocks_in_use(), 3);
        assert_eq!(pool.blocks_peak(), 3);
        pool.release(b);
        assert_eq!(pool.free_blocks(), 1);
        let b2 = pool.alloc().unwrap();
        assert_eq!(b2, b, "LIFO free list reuses the last released block");
        pool.release(a);
        pool.release(b2);
        pool.release(c);
        assert_eq!(pool.free_blocks(), 3);
        assert_eq!(pool.blocks_peak(), 3, "peak persists after release");
        pool.check_invariants();
    }

    #[test]
    fn retain_keeps_block_alive_until_last_release() {
        let mut pool = BlockPool::new(&cfg(), 2, 2);
        let b = pool.alloc().unwrap();
        pool.retain(b);
        pool.retain(b);
        assert_eq!(pool.ref_count(b), 3);
        pool.release(b);
        pool.release(b);
        assert_eq!(pool.free_blocks(), 1, "still one live reference");
        pool.release(b);
        assert_eq!(pool.free_blocks(), 2);
        pool.check_invariants();
    }

    #[test]
    fn cow_preserves_fork_prefix_and_isolates_divergence() {
        let c = cfg();
        let mut pool = BlockPool::new(&c, 4, 8);
        let mut a = PagedKvCache::new();
        // Fill 6 positions (one full block + 2 slots of the next).
        for p in 0..6 {
            a.prepare_append(&mut pool).unwrap();
            for layer in 0..c.n_layers {
                let k = vec![p as f32; c.d_model];
                let v = vec![-(p as f32); c.d_model];
                a.write_kv(&mut pool, layer, &k, &v);
            }
            a.advance();
        }
        let mut b = a.fork(&mut pool);
        assert_eq!(a.chain(), b.chain());
        assert_eq!(pool.ref_count(a.chain()[1]), 2);

        // Divergent append on the fork: must COW the partial tail block.
        b.prepare_append(&mut pool).unwrap();
        assert_ne!(a.chain()[1], b.chain()[1], "COW must copy the shared tail");
        assert_eq!(a.chain()[0], b.chain()[0], "full block stays shared");
        let (k99, v99) = (vec![99.0; c.d_model], vec![-99.0; c.d_model]);
        for layer in 0..c.n_layers {
            b.write_kv(&mut pool, layer, &k99, &v99);
        }
        b.advance();
        // a's view of positions 4..6 is untouched.
        let bs = pool.block_size();
        for p in 4..6 {
            let row = a.chain()[p / bs] * bs + p % bs;
            assert_eq!(pool.layer_k(0).row(row)[0], p as f32);
        }
        // b's copied prefix (4, 5) matches and its new position 6 diverged.
        for p in 4..6 {
            let row = b.chain()[p / bs] * bs + p % bs;
            assert_eq!(pool.layer_k(0).row(row)[0], p as f32, "COW lost the copied prefix");
        }
        let row = b.chain()[1] * bs + 2;
        assert_eq!(pool.layer_k(0).row(row)[0], 99.0);

        a.release(&mut pool);
        b.release(&mut pool);
        assert_eq!(pool.free_blocks(), 8);
        pool.check_invariants();
    }

    #[test]
    fn pool_exhaustion_is_a_typed_error() {
        let mut pool = BlockPool::new(&cfg(), 2, 1);
        let mut a = PagedKvCache::new();
        a.prepare_append(&mut pool).unwrap();
        a.advance();
        a.advance(); // block full at 2 tokens
        let mut b = PagedKvCache::new();
        match b.prepare_append(&mut pool) {
            Err(CacheError::PoolExhausted { .. }) => {}
            other => panic!("expected PoolExhausted, got {other:?}"),
        }
        a.release(&mut pool);
        assert!(b.prepare_append(&mut pool).is_ok(), "freed block is reusable");
        b.release(&mut pool);
        pool.check_invariants();
    }

    #[test]
    fn truncate_releases_whole_blocks_and_is_cow_safe() {
        let c = cfg();
        let mut pool = BlockPool::new(&c, 4, 8);
        let mut a = PagedKvCache::new();
        for p in 0..10 {
            a.prepare_append(&mut pool).unwrap();
            for layer in 0..c.n_layers {
                let k = vec![p as f32; c.d_model];
                a.write_kv(&mut pool, layer, &k, &k);
            }
            a.advance();
        }
        assert_eq!(a.blocks_held(), 3);

        // Fork, then roll the fork back across a block boundary: only the
        // fork's own references are dropped; the original keeps its chain.
        let mut b = a.fork(&mut pool);
        b.truncate(&mut pool, 5);
        assert_eq!(b.len(), 5);
        assert_eq!(b.blocks_held(), 2, "5 tokens need 2 blocks of 4");
        assert_eq!(pool.ref_count(a.chain()[2]), 1, "tail block back to the sole owner");
        assert_eq!(pool.ref_count(a.chain()[1]), 2, "kept blocks stay shared");
        pool.check_invariants();

        // The original's contents at the rolled-back positions are intact.
        let bs = pool.block_size();
        for p in 4..10 {
            let row = a.chain()[p / bs] * bs + p % bs;
            assert_eq!(pool.layer_k(0).row(row)[0], p as f32, "truncate mutated shared KV");
        }

        // Re-appending on the fork COWs the shared (kept) tail block before
        // writing, so the original's position 5..8 stay untouched.
        b.prepare_append(&mut pool).unwrap();
        assert_ne!(b.chain()[1], a.chain()[1], "shared tail must COW after rollback");
        for layer in 0..c.n_layers {
            b.write_kv(&mut pool, layer, &[77.0; 8], &[77.0; 8]);
        }
        b.advance();
        for p in 4..10 {
            let row = a.chain()[p / bs] * bs + p % bs;
            assert_eq!(pool.layer_k(0).row(row)[0], p as f32);
        }

        // Truncate to a block boundary and to zero.
        b.truncate(&mut pool, 4);
        assert_eq!(b.blocks_held(), 1);
        a.truncate(&mut pool, 0);
        assert_eq!((a.len(), a.blocks_held()), (0, 0));
        b.release(&mut pool);
        assert_eq!(pool.free_blocks(), 8);
        pool.check_invariants();
    }

    #[test]
    fn prepare_append_n_allocs_ahead_and_rolls_back_on_exhaustion() {
        let c = cfg();
        let mut pool = BlockPool::new(&c, 2, 3);
        let mut a = PagedKvCache::new();
        a.prepare_append_n(&mut pool, 4).unwrap();
        assert_eq!(a.blocks_held(), 2, "4 tokens at block size 2 = 2 blocks");
        assert_eq!(a.len(), 0, "prepare commits nothing");
        // Idempotent for already-prepared positions.
        a.prepare_append_n(&mut pool, 4).unwrap();
        assert_eq!(a.blocks_held(), 2);
        for pos in 0..4 {
            for layer in 0..c.n_layers {
                a.write_kv_at(&mut pool, layer, pos, &[pos as f32; 8], &[0.0; 8]);
            }
        }
        a.advance_n(4);
        assert_eq!(a.len(), 4);

        // Asking past the pool: typed error, chain rolled back to cover
        // exactly the committed tokens, nothing leaked.
        let mut b = PagedKvCache::new();
        match b.prepare_append_n(&mut pool, 4) {
            Err(CacheError::PoolExhausted { .. }) => {}
            other => panic!("expected PoolExhausted, got {other:?}"),
        }
        assert_eq!(b.blocks_held(), 0, "failed prepare must roll its allocations back");
        pool.check_invariants();
        a.release(&mut pool);
        assert!(b.prepare_append_n(&mut pool, 4).is_ok());
        b.release(&mut pool);
        assert_eq!(pool.free_blocks(), 3);
    }

    /// Randomized alloc/append/fork/release/truncate schedule; the pool
    /// invariants (refcount ↔ free-list consistency, conservation of
    /// blocks) must hold at every step, and held-block accounting must
    /// reconcile. The truncate arm models speculative-decode rollback
    /// interleaved with forks (shared chains) and multi-token prepares.
    #[test]
    fn randomized_alloc_free_fork_keeps_invariants() {
        let c = cfg();
        for seed in 0..6u64 {
            let mut rng = Xoshiro256::new(0xB10C ^ seed);
            let bs = 1 + rng.below(5);
            let n_blocks = 4 + rng.below(12);
            let mut pool = BlockPool::new(&c, bs, n_blocks);
            let mut caches: Vec<PagedKvCache> = Vec::new();
            for _ in 0..300 {
                match rng.below(7) {
                    0 => caches.push(PagedKvCache::new()),
                    1 | 2 => {
                        // Append one token to a random cache (may exhaust).
                        if let Some(i) = (!caches.is_empty()).then(|| rng.below(caches.len())) {
                            if caches[i].prepare_append(&mut pool).is_ok() {
                                for layer in 0..c.n_layers {
                                    let k = vec![rng.gaussian(); c.d_model];
                                    caches[i].write_kv(&mut pool, layer, &k, &k);
                                }
                                caches[i].advance();
                            }
                        }
                    }
                    3 => {
                        if let Some(i) = (!caches.is_empty()).then(|| rng.below(caches.len())) {
                            let f = caches[i].fork(&mut pool);
                            caches.push(f);
                        }
                    }
                    4 => {
                        // Speculative rollback: truncate to a random shorter
                        // length (possibly across shared/forked blocks).
                        if let Some(i) = (!caches.is_empty()).then(|| rng.below(caches.len())) {
                            let new_len = rng.below(caches[i].len() + 1);
                            caches[i].truncate(&mut pool, new_len);
                        }
                    }
                    5 => {
                        // Multi-token prepare (speculative verify window):
                        // may exhaust the pool; either way nothing commits.
                        if let Some(i) = (!caches.is_empty()).then(|| rng.below(caches.len())) {
                            let n = 1 + rng.below(2 * bs);
                            let _ = caches[i].prepare_append_n(&mut pool, n);
                            // Roll back to the committed length: uncommitted
                            // prepared blocks must release cleanly too.
                            let len = caches[i].len();
                            caches[i].truncate(&mut pool, len);
                        }
                    }
                    _ => {
                        if let Some(i) = (!caches.is_empty()).then(|| rng.below(caches.len())) {
                            let mut cche = caches.swap_remove(i);
                            cche.release(&mut pool);
                        }
                    }
                }
                pool.check_invariants();
                // Total references held by caches == sum of live refcounts.
                let held: usize = caches.iter().map(|ca| ca.blocks_held()).sum();
                let refs: usize = (0..pool.n_blocks()).map(|b| pool.ref_count(b) as usize).sum();
                assert_eq!(held, refs, "seed {seed}: dangling or leaked references");
            }
            for mut cche in caches {
                cche.release(&mut pool);
            }
            assert_eq!(pool.free_blocks(), n_blocks, "seed {seed}: leaked blocks");
            pool.check_invariants();
        }
    }
}
