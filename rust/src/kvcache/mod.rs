//! Paged KV-cache subsystem: block-pool allocator, per-sequence paged
//! caches with copy-on-write, and a prefix trie for shared-prefix reuse.
//!
//! This is the serving engine's memory-management layer (DESIGN.md §2b).
//! Instead of one dense `max_seq × d_model` K and V matrix per layer per
//! decode slot, KV rows live in a [`BlockPool`] of fixed-size token blocks
//! shared by every in-flight sequence:
//!
//! * [`pool::BlockPool`] — ref-counted blocks behind a free list; one
//!   logical block spans all layers.
//! * [`pool::PagedKvCache`] — a sequence's view: a chain of block ids plus
//!   a length, growing a block at a time, with copy-on-write on the first
//!   divergent append to a shared tail block ([`pool::PagedKvCache::fork`]).
//! * [`trie::PrefixTrie`] — prompt-prefix → block-chain map at block
//!   granularity, so identical prompt prefixes (system prompts) share
//!   blocks and skip prefill entirely; unreferenced entries are evicted
//!   under pool pressure.
//!
//! The decode path over this storage is `model::decode_step_paged` /
//! `model::PagedDecodeBatch`; the block-strided attention kernel is
//! [`crate::tensor::attention_over_paged`], bit-for-bit identical to the
//! contiguous-cache kernel (the §2a determinism contract extends to paging).

pub mod pool;
pub mod trie;

pub use pool::{BlockPool, PagedKvCache};
pub use trie::PrefixTrie;

/// Typed decode-path cache failures. These replace the former
/// `assert!(pos < cfg.max_seq, "KV cache full")` panics: the engine maps
/// them to graceful per-sequence retirement (a hostile prompt must not
/// abort a whole engine pass), and the paged batcher maps pool exhaustion
/// to eviction/preemption instead of aborting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheError {
    /// The sequence reached the model's positional capacity (`max_seq`).
    CacheFull {
        /// Batch row of the offending sequence (0 for single-sequence ops).
        seq: usize,
        /// Position that could not be appended.
        pos: usize,
        /// The model's `max_seq`.
        capacity: usize,
    },
    /// The block pool has no free block for the next append.
    PoolExhausted {
        /// Batch row of the offending sequence (0 for single-sequence ops).
        seq: usize,
        needed: usize,
        available: usize,
    },
}

impl CacheError {
    /// Batch row the error refers to.
    pub fn seq(&self) -> usize {
        match *self {
            CacheError::CacheFull { seq, .. } | CacheError::PoolExhausted { seq, .. } => seq,
        }
    }

    /// Same error re-attributed to batch row `seq` (helpers report row 0).
    pub fn with_seq(self, new_seq: usize) -> Self {
        match self {
            CacheError::CacheFull { pos, capacity, .. } => {
                CacheError::CacheFull { seq: new_seq, pos, capacity }
            }
            CacheError::PoolExhausted { needed, available, .. } => {
                CacheError::PoolExhausted { seq: new_seq, needed, available }
            }
        }
    }
}

impl std::fmt::Display for CacheError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            CacheError::CacheFull { seq, pos, capacity } => {
                write!(f, "KV cache full: seq {seq} at position {pos} (max_seq {capacity})")
            }
            CacheError::PoolExhausted { seq, needed, available } => {
                write!(
                    f,
                    "KV block pool exhausted: seq {seq} needs {needed} block(s), {available} free"
                )
            }
        }
    }
}

impl std::error::Error for CacheError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_error_display_and_seq_rewrite() {
        let e = CacheError::CacheFull { seq: 0, pos: 64, capacity: 64 };
        assert!(e.to_string().contains("position 64"));
        assert_eq!(e.with_seq(3).seq(), 3);
        let p = CacheError::PoolExhausted { seq: 1, needed: 2, available: 0 };
        assert!(p.to_string().contains("exhausted"));
        assert_eq!(p.with_seq(5).seq(), 5);
    }
}
