//! Prefix trie: prompt-token prefixes → shared KV block chains.
//!
//! The trie is keyed at **block granularity**: each edge is one full block
//! of `block_size` prompt tokens and each node owns one pool block holding
//! that edge's K/V rows (for every layer). Because a block's contents are a
//! deterministic function of the *entire* token path from the root and of
//! the positions along it, two sequences whose prompts share a token prefix
//! can share the prefix's blocks bit-for-bit — prefill for those tokens is
//! skipped entirely (the AdapterDrop lesson: the fastest computation is the
//! one you don't run).
//!
//! The trie holds its own reference on every adopted block, so shared
//! prefixes survive sequence retirement. Under pool pressure,
//! [`PrefixTrie::evict`] releases leaf-first any block referenced *only* by
//! the trie (refcount 1), i.e. prefixes with no live reader.
//!
//! `BTreeMap` keeps walk/evict order deterministic across runs.

use std::collections::BTreeMap;

use super::pool::BlockPool;

#[derive(Default)]
struct Node {
    /// Pool block holding this edge's `block_size` token rows.
    block: usize,
    children: BTreeMap<Vec<u32>, Node>,
}

/// Trie over full prompt blocks. See the module docs for sharing rules.
#[derive(Default)]
pub struct PrefixTrie {
    children: BTreeMap<Vec<u32>, Node>,
    /// Blocks currently referenced by trie nodes.
    held: usize,
}

impl PrefixTrie {
    pub fn new() -> Self {
        Self::default()
    }

    /// Blocks the trie currently holds a reference on.
    pub fn blocks_held(&self) -> usize {
        self.held
    }

    /// Longest shared prefix of `tokens` present in the trie, capped at
    /// `max_blocks` blocks. Returns the block chain with **one reference
    /// per block retained for the caller** (release via
    /// `PagedKvCache::release` or `BlockPool::release`).
    pub fn lookup(
        &self,
        tokens: &[u32],
        max_blocks: usize,
        pool: &mut BlockPool,
    ) -> Vec<usize> {
        let bs = pool.block_size();
        let mut chain = Vec::new();
        let mut level = &self.children;
        while chain.len() < max_blocks {
            let start = chain.len() * bs;
            if start + bs > tokens.len() {
                break;
            }
            match level.get(&tokens[start..start + bs]) {
                Some(node) => {
                    pool.retain(node.block);
                    chain.push(node.block);
                    level = &node.children;
                }
                None => break,
            }
        }
        chain
    }

    /// Register the first `chain.len()` full blocks of `tokens` (the
    /// caller's live chain, block `i` covering tokens `[i·bs, (i+1)·bs)`).
    /// Nodes already present keep their existing block (first writer wins —
    /// identical contents by determinism); newly-adopted blocks get one
    /// trie-owned reference.
    pub fn insert(&mut self, tokens: &[u32], chain: &[usize], pool: &mut BlockPool) {
        let bs = pool.block_size();
        debug_assert!(chain.len() * bs <= tokens.len(), "insert beyond full blocks");
        let mut level = &mut self.children;
        for (i, &block) in chain.iter().enumerate() {
            let key = tokens[i * bs..(i + 1) * bs].to_vec();
            let node = level.entry(key).or_insert_with(|| {
                pool.retain(block);
                self.held += 1;
                Node { block, children: BTreeMap::new() }
            });
            // On a pre-existing node with a different block, keep the
            // existing one; the caller's copy simply isn't shared. Either
            // way the walk continues through the node that *is* in the trie.
            level = &mut node.children;
        }
    }

    /// Release up to `need` blocks whose only reference is the trie's own
    /// (no live reader), deepest-first so inner nodes become evictable as
    /// their children go. Returns how many blocks were freed.
    pub fn evict(&mut self, pool: &mut BlockPool, need: usize) -> usize {
        if need == 0 {
            return 0;
        }
        let mut freed = 0;
        Self::evict_level(&mut self.children, pool, need, &mut freed);
        self.held -= freed;
        freed
    }

    fn evict_level(
        level: &mut BTreeMap<Vec<u32>, Node>,
        pool: &mut BlockPool,
        need: usize,
        freed: &mut usize,
    ) {
        level.retain(|_, node| {
            if *freed >= need {
                return true;
            }
            Self::evict_level(&mut node.children, pool, need, freed);
            // A node is removable once it has no children and no reader
            // other than the trie itself.
            if node.children.is_empty() && *freed < need && pool.ref_count(node.block) == 1 {
                pool.release(node.block);
                *freed += 1;
                false
            } else {
                true
            }
        });
    }

    /// Drop every trie reference (shutdown / tests).
    pub fn clear(&mut self, pool: &mut BlockPool) {
        Self::clear_level(&mut self.children, pool);
        self.held = 0;
    }

    fn clear_level(level: &mut BTreeMap<Vec<u32>, Node>, pool: &mut BlockPool) {
        for node in level.values_mut() {
            Self::clear_level(&mut node.children, pool);
            pool.release(node.block);
        }
        level.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Arch, ModelConfig};

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            arch: Arch::SwiGlu,
            d_model: 8,
            n_layers: 1,
            n_heads: 2,
            d_hidden: 16,
            vocab: 32,
            max_seq: 64,
            rope_theta: 10_000.0,
            norm_eps: 1e-5,
        }
    }

    /// Allocate a chain of `n` blocks directly from the pool.
    fn chain(pool: &mut BlockPool, n: usize) -> Vec<usize> {
        (0..n).map(|_| pool.alloc().unwrap()).collect()
    }

    #[test]
    fn lookup_matches_longest_full_block_prefix() {
        let mut pool = BlockPool::new(&cfg(), 2, 8);
        let mut trie = PrefixTrie::new();
        let toks: Vec<u32> = vec![1, 2, 3, 4, 5, 6];
        let ch = chain(&mut pool, 3);
        trie.insert(&toks, &ch, &mut pool);
        assert_eq!(trie.blocks_held(), 3);

        // Full match, capped by max_blocks.
        let hit = trie.lookup(&toks, 2, &mut pool);
        assert_eq!(hit, ch[..2].to_vec());
        assert_eq!(pool.ref_count(ch[0]), 3, "owner + trie + lookup");
        for &b in &hit {
            pool.release(b);
        }

        // Diverging third block: only two blocks match.
        let other: Vec<u32> = vec![1, 2, 3, 4, 9, 9];
        let hit = trie.lookup(&other, 8, &mut pool);
        assert_eq!(hit.len(), 2);
        for &b in &hit {
            pool.release(b);
        }

        // Shorter than one block: no match.
        assert!(trie.lookup(&[1], 8, &mut pool).is_empty());

        for &b in &ch {
            pool.release(b);
        }
        trie.clear(&mut pool);
        assert_eq!(pool.free_blocks(), 8);
    }

    #[test]
    fn insert_keeps_first_writer_on_duplicate_paths() {
        let mut pool = BlockPool::new(&cfg(), 2, 8);
        let mut trie = PrefixTrie::new();
        let toks: Vec<u32> = vec![7, 7, 8, 8];
        let a = chain(&mut pool, 2);
        let b = chain(&mut pool, 2);
        trie.insert(&toks, &a, &mut pool);
        trie.insert(&toks, &b, &mut pool); // duplicate path: ignored
        assert_eq!(trie.blocks_held(), 2);
        let hit = trie.lookup(&toks, 8, &mut pool);
        assert_eq!(hit, a, "first writer's blocks stay in the trie");
        for &x in hit.iter().chain(&a).chain(&b) {
            pool.release(x);
        }
        trie.clear(&mut pool);
        assert_eq!(pool.free_blocks(), 8);
    }

    /// Randomized publish (insert) / adopt (lookup) / rollback (truncate) /
    /// evict interleavings over one pool: refcount ↔ free-list invariants
    /// must hold at every step, rollback must never free a block the trie
    /// still holds, and a final clear + release must return every block.
    #[test]
    fn randomized_publish_rollback_evict_keeps_invariants() {
        use crate::kvcache::PagedKvCache;
        use crate::util::rng::Xoshiro256;
        let c = cfg();
        for seed in 0..4u64 {
            let mut rng = Xoshiro256::new(0x7121E ^ seed);
            let bs = 2usize;
            let n_blocks = 12;
            let mut pool = BlockPool::new(&c, bs, n_blocks);
            let mut trie = PrefixTrie::new();
            // A few fixed prompts so lookups actually hit published paths.
            let prompts: Vec<Vec<u32>> =
                vec![vec![1, 2, 3, 4, 5, 6], vec![1, 2, 3, 4, 9, 9], vec![7, 7, 8, 8]];
            let mut seqs: Vec<(usize, PagedKvCache)> = Vec::new(); // (prompt idx, cache)
            for _ in 0..200 {
                match rng.below(6) {
                    0 => {
                        // Admit: adopt the longest published prefix.
                        let pi = rng.below(prompts.len());
                        let chain = trie.lookup(&prompts[pi], 8, &mut pool);
                        let tokens = chain.len() * bs;
                        seqs.push((pi, PagedKvCache::from_shared_prefix(chain, tokens, bs)));
                    }
                    1 | 2 => {
                        // Grow a sequence toward its full prompt (may COW).
                        if let Some(i) = (!seqs.is_empty()).then(|| rng.below(seqs.len())) {
                            let (pi, cache) = &mut seqs[i];
                            if cache.len() < prompts[*pi].len()
                                && cache.prepare_append(&mut pool).is_ok()
                            {
                                for layer in 0..c.n_layers {
                                    let k = vec![cache.len() as f32; c.d_model];
                                    cache.write_kv(&mut pool, layer, &k, &k);
                                }
                                cache.advance();
                            }
                        }
                    }
                    3 => {
                        // Publish full prompt blocks, then speculatively
                        // overshoot and roll back — the published boundary
                        // must survive (trie refs + this chain's refs).
                        if let Some(i) = (!seqs.is_empty()).then(|| rng.below(seqs.len())) {
                            let (pi, cache) = &mut seqs[i];
                            let full = cache.len() / bs;
                            if full > 0 {
                                trie.insert(&prompts[*pi], &cache.chain()[..full], &mut pool);
                            }
                            let committed = cache.len();
                            if cache.prepare_append_n(&mut pool, bs + 1).is_ok() {
                                cache.advance_n(bs + 1);
                            }
                            cache.truncate(&mut pool, committed);
                            assert!(
                                cache.blocks_held() * bs >= committed,
                                "rollback released a block still covering committed tokens"
                            );
                        }
                    }
                    4 => {
                        let _ = trie.evict(&mut pool, 1 + rng.below(3));
                    }
                    _ => {
                        if let Some(i) = (!seqs.is_empty()).then(|| rng.below(seqs.len())) {
                            let (_, mut cache) = seqs.swap_remove(i);
                            cache.release(&mut pool);
                        }
                    }
                }
                pool.check_invariants();
                let held: usize =
                    seqs.iter().map(|(_, s)| s.blocks_held()).sum::<usize>() + trie.blocks_held();
                let refs: usize =
                    (0..pool.n_blocks()).map(|b| pool.ref_count(b) as usize).sum();
                assert_eq!(held, refs, "seed {seed}: dangling or leaked references");
            }
            for (_, mut s) in seqs {
                s.release(&mut pool);
            }
            trie.clear(&mut pool);
            assert_eq!(pool.free_blocks(), n_blocks, "seed {seed}: leaked blocks");
            pool.check_invariants();
        }
    }

    #[test]
    fn evict_frees_only_unreferenced_leaf_first() {
        let mut pool = BlockPool::new(&cfg(), 2, 8);
        let mut trie = PrefixTrie::new();
        let toks: Vec<u32> = vec![1, 2, 3, 4, 5, 6];
        let ch = chain(&mut pool, 3);
        trie.insert(&toks, &ch, &mut pool);
        // Simulate a live reader of the first two blocks; drop our own refs
        // on the third.
        pool.release(ch[2]);
        // Trie-only references: ch[2] (leaf). ch[0]/ch[1] still ours.
        let freed = trie.evict(&mut pool, 8);
        assert_eq!(freed, 1, "only the unreferenced leaf is evictable");
        assert_eq!(trie.blocks_held(), 2);
        // Release our refs; now the rest becomes evictable, deepest first.
        pool.release(ch[0]);
        pool.release(ch[1]);
        let freed = trie.evict(&mut pool, 1);
        assert_eq!(freed, 1, "evict honours the `need` cap");
        assert_eq!(trie.evict(&mut pool, 8), 1);
        assert_eq!(pool.free_blocks(), 8);
        assert_eq!(trie.blocks_held(), 0);
    }
}
