//! `rana` — leader entrypoint and CLI.
//!
//! Subcommands:
//! * `gen-data`       — generate the synthlang corpus into `artifacts/`
//!   (single source of truth shared with the python build path);
//! * `serve`          — start the serving coordinator (TCP line protocol);
//! * `adapt`          — adapt a trained model and print the report;
//! * `eval`           — perplexity + downstream accuracy of a (possibly
//!   adapted) model;
//! * `decode`         — decode from a prompt: adapted (`--method/--rate`
//!   or runtime `--budget`), sampled (`--temperature/--top-k/--top-p/
//!   --seed`), and optionally self-speculative (`--spec-k/--spec-draft`);
//! * `runtime-check`  — load an HLO artifact via PJRT and verify parity
//!   against the native engine.

use std::sync::Arc;

use rana::adapters::calibrate::{self, CalibOptions, Method};
use rana::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> anyhow::Result<()> {
    match args.subcommand() {
        Some("gen-data") => gen_data(args),
        Some("serve") => serve(args),
        Some("adapt") => adapt_cmd(args),
        Some("eval") => eval_cmd(args),
        Some("decode") => decode_cmd(args),
        Some("runtime-check") => runtime_check(args),
        Some(other) => anyhow::bail!("unknown subcommand {other:?} (see README)"),
        None => {
            println!("{}", usage());
            Ok(())
        }
    }
}

fn usage() -> &'static str {
    "rana — Adaptive Rank Allocation serving stack\n\
     usage: rana <gen-data|serve|adapt|eval|decode|runtime-check> [--flags]\n\
     see README.md for the full CLI reference"
}

/// Generate the canonical corpus files into artifacts/.
fn gen_data(args: &Args) -> anyhow::Result<()> {
    let dir = rana::util::artifacts_dir();
    let train_mb = args.get_f64("train-mb", 4.0);
    let heldout_kb = args.get_f64("heldout-kb", 512.0);
    rana::data::export_corpus(
        &dir,
        (train_mb * 1e6) as usize,
        (heldout_kb * 1e3) as usize,
    )?;
    println!(
        "wrote corpus_train.txt ({train_mb} MB) + corpus_heldout.txt ({heldout_kb} KB) to {}",
        dir.display()
    );
    Ok(())
}

/// Load a model and calibration data, honoring --model/--method/--rate.
fn load_and_adapt(
    args: &Args,
) -> anyhow::Result<(Arc<rana::model::Model>, rana::adapters::AdaptedModel, calibrate::AdaptReport)>
{
    let name = args.get_str("model", "llama-sim");
    let model = Arc::new(rana::model::Model::load(&rana::model::model_dir(&name))?);
    let method = Method::parse(&args.get_str("method", "rana"))?;
    let rate = args.get_f64("rate", 0.3);
    if rate <= 0.0 {
        let adapted = rana::adapters::AdaptedModel::unadapted(Arc::clone(&model));
        return Ok((model, adapted, calibrate::AdaptReport::default()));
    }
    let corpus = rana::data::generate_corpus(600_000, 1_000);
    let opts = CalibOptions {
        n_fit: args.get_usize("calib", 2048),
        n_eval: 256,
        window: 128,
        seed: args.get_u64("seed", 0xCA11B),
    };
    let calib = calibrate::collect(&model, &corpus.train, &opts);
    let (adapted, report) =
        calibrate::adapt(Arc::clone(&model), &calib, method, rate, 512, opts.seed);
    Ok((model, adapted, report))
}

fn adapt_cmd(args: &Args) -> anyhow::Result<()> {
    let (_, adapted, report) = load_and_adapt(args)?;
    println!("method={}", adapted.method);
    println!(
        "achieved compression: total={:.1}% mlp={:.1}% qkv={:.1}%",
        report.total_compression * 100.0,
        report.mlp_compression * 100.0,
        report.qkv_compression * 100.0
    );
    for (l, lr) in report.layers.iter().enumerate() {
        println!(
            "layer {l}: mlp_err={:.2}% qkv_err={:.2}%",
            lr.mlp_err * 100.0,
            lr.qkv_err * 100.0
        );
    }
    Ok(())
}

fn eval_cmd(args: &Args) -> anyhow::Result<()> {
    let (model, adapted, report) = load_and_adapt(args)?;
    let ppl_tokens = args.get_usize("ppl-tokens", 20_000);
    let items = args.get_usize("items", 60);
    let corpus = rana::data::generate_corpus(1_000, 2 * ppl_tokens + 2_000);
    let ppl = rana::eval::perplexity(&adapted, &corpus.heldout, ppl_tokens, 256);
    let g = rana::data::grammar();
    let suites = rana::data::tasks::all_suites(&g, items, 0xE7A1);
    let accs = rana::eval::task_accuracies(&adapted, &suites);
    println!("model={} method={}", model.cfg.name, adapted.method);
    println!("compression: {:.1}%", report.total_compression * 100.0);
    println!("ppl: {ppl:.3}");
    let mut avg = 0.0;
    for (s, a) in suites.iter().zip(&accs) {
        println!("  {:<14} {:.2}%", s.name, a * 100.0);
        avg += a;
    }
    println!("avg acc: {:.2}%", avg / accs.len() as f64 * 100.0);
    Ok(())
}

/// `rana decode`: adapted + sampled + (optionally) speculative decoding
/// from a prompt, driven through the same engine session surface the
/// server uses.
///
/// Adaptation: `--method`/`--rate` build a fixed-budget adapter (as in
/// `rana eval`); `--budget <r>` instead builds a runtime-budget model
/// calibrated at `{r, spec-draft}` and serves at ambient rate `r`
/// (`--budget 0` = dense target). `--spec-k N` enables self-speculative
/// decoding (drafting at `--spec-draft`, default 0.5). Sampling:
/// `--temperature/--top-k/--top-p/--seed` (temperature 0 = exact greedy).
fn decode_cmd(args: &Args) -> anyhow::Result<()> {
    use rana::coordinator::engine::{DecodeSession as _, Engine, SeqEvent, SessionRequest};
    use rana::coordinator::metrics::Metrics;

    let prompt = args.get_str("prompt", "the ");
    let n = args.get_usize("tokens", 64);
    let spec_k = args.get_usize("spec-k", 0);
    // Compression rates live in [0, 1): clamp like the serve path so the
    // drafted tier is always a calibratable rate.
    let spec_draft = args.get_f64("spec-draft", 0.5).clamp(0.0, 0.99);
    let sampling = rana::model::Sampling {
        temperature: args.get_f64("temperature", 0.0),
        top_k: args.get_usize("top-k", 0),
        top_p: args.get_f64("top-p", 1.0),
        seed: args.get_u64("seed", 0),
    };

    let budget = args.get_opt("budget").and_then(|b| b.parse::<f64>().ok());
    let adapted = if budget.is_some() || spec_k > 0 {
        // Runtime-budget path: one calibration serves the target budget
        // AND the speculative draft tier.
        let name = args.get_str("model", "llama-sim");
        let model = Arc::new(rana::model::load_or_random(&name, 0x5E12)?);
        let target = budget.unwrap_or(0.0).clamp(0.0, 0.99);
        let mut tiers: Vec<f64> = [target, if spec_k > 0 { spec_draft } else { 0.0 }]
            .into_iter()
            .filter(|&r| r > 0.0)
            .collect();
        tiers.sort_by(|a, b| a.partial_cmp(b).unwrap());
        tiers.dedup();
        if tiers.is_empty() {
            rana::adapters::AdaptedModel::unadapted(model)
        } else {
            let corpus = rana::data::generate_corpus(400_000, 1_000);
            // Calibration seed is fixed (like `serve`'s build_engine):
            // --seed is the *sampling* seed and must not change the
            // adapted model itself.
            let opts = CalibOptions {
                n_fit: args.get_usize("calib", 1024),
                n_eval: 128,
                window: 128,
                seed: 0xCA11B,
            };
            let calib = calibrate::collect(&model, &corpus.train, &opts);
            let (adapted, _) =
                calibrate::adapt_runtime(Arc::clone(&model), &calib, &tiers, 512, opts.seed);
            adapted.set_budget(target);
            adapted
        }
    } else if args.get_f64("rate", 0.0) > 0.0 {
        // Fixed-budget path honoring --method/--rate. Calibration must not
        // see the *sampling* seed: strip --seed so load_and_adapt keeps
        // its own fixed calibration default.
        let mut calib_args = args.clone();
        calib_args.options.remove("seed");
        let (_, adapted, _) = load_and_adapt(&calib_args)?;
        adapted
    } else {
        // No adaptation flags: plain dense decode (the pre-existing
        // smoke/demo default).
        let name = args.get_str("model", "llama-sim");
        let model = Arc::new(rana::model::Model::load(&rana::model::model_dir(&name))?);
        rana::adapters::AdaptedModel::unadapted(model)
    };

    let engine = rana::coordinator::engine::NativeEngine::new(Arc::new(adapted))
        .with_decode_capacity(1)
        .with_spec(spec_k, spec_draft);
    let metrics = Arc::new(Metrics::new());
    engine.set_metrics(Arc::clone(&metrics));
    let mut session = engine.begin_decode_session().expect("native decode session");
    let req = SessionRequest {
        prompt: prompt.clone(),
        max_new: n,
        sampling,
        ..SessionRequest::default()
    };
    session.try_join(&req).expect("fresh session has a free slot");
    let text = loop {
        let events = session.step();
        let finished = events.into_iter().find_map(|e| match e {
            SeqEvent::Finished { text, .. } => Some(text),
            _ => None,
        });
        if let Some(t) = finished {
            break t;
        }
        if session.active() == 0 {
            break prompt.clone();
        }
    };
    println!("{text}");
    if spec_k > 0 {
        use std::sync::atomic::Ordering;
        eprintln!(
            "spec: draft_tokens={} accepted={} acceptance={:.2} rollbacks={}",
            metrics.draft_tokens.load(Ordering::Relaxed),
            metrics.accepted_tokens.load(Ordering::Relaxed),
            metrics.spec_acceptance(),
            metrics.spec_rollbacks.load(Ordering::Relaxed),
        );
    }
    Ok(())
}

fn serve(args: &Args) -> anyhow::Result<()> {
    // `--tiers 0.2,0.35,0.5` overrides the controller's compression tiers.
    let budget_tiers: Vec<f64> = args
        .get_opt("tiers")
        .map(|s| s.split(',').filter_map(|t| t.trim().parse().ok()).collect())
        .unwrap_or_default();
    let defaults = rana::coordinator::ServerConfig::default();
    let cfg = rana::coordinator::ServerConfig {
        model: args.get_str("model", "llama-sim"),
        port: args.get_usize("port", 7070) as u16,
        max_batch: args.get_usize("max-batch", 8),
        target_compression: args.get_f64("rate", 0.0),
        adaptive_budget: args.get_flag("adaptive-budget"),
        budget_tiers,
        engine: args.get_str("engine", "native"),
        calib_fit: args.get_usize("calib", defaults.calib_fit),
        spec_k: args.get_usize("spec-k", defaults.spec_k),
        spec_draft: args.get_f64("spec-draft", defaults.spec_draft),
        limits: rana::coordinator::protocol::Limits {
            max_tokens_cap: args.get_usize("max-tokens", defaults.limits.max_tokens_cap),
            max_line_bytes: args.get_usize("max-line-bytes", defaults.limits.max_line_bytes),
        },
        trace_out: args.get_opt("trace-out").map(String::from),
        prefill_chunk: args.get_usize("prefill-chunk", defaults.prefill_chunk),
        slo_ttft_ms: args.get_opt("slo-ttft-ms").and_then(|s| s.parse().ok()),
        slo_itl_ms: args.get_opt("slo-itl-ms").and_then(|s| s.parse().ok()),
        metrics_addr: args.get_opt("metrics-addr").map(String::from),
        trace_ring: args.get_usize("trace-ring", defaults.trace_ring),
    };
    rana::coordinator::serve(cfg)
}

fn runtime_check(args: &Args) -> anyhow::Result<()> {
    let name = args.get_str("model", "llama-sim");
    rana::runtime::parity_check(&name)
}
