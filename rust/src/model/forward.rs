//! Generic forward pass over pluggable block operators.
//!
//! [`BlockOps`] abstracts the three places adapters intervene — QKV, the
//! attention output projection (never adapted, but kept symmetric) and the
//! MLP — over both execution paths:
//!
//! * the **sequence path** (`forward_seq`): GEMM-based, used for
//!   perplexity / task scoring / calibration capture;
//! * the **decode path** (`decode_step`): GEMV + KV-cache, used by the
//!   serving coordinator and latency benchmarks (where masked skipping
//!   yields real wall-clock wins).
//!
//! The dense model implements `BlockOps` here; RaNA/CATS/… adapted models
//! implement it in [`crate::adapters`], and every evaluation harness is
//! generic over it — the paper's technique is a first-class plug-in, not a
//! fork of the model code.

use super::config::{Arch, ModelConfig};
use super::ops;
use super::weights::ModelWeights;
use crate::flops::measured::{self, FlopPhases};
use crate::kvcache::CacheError;
use crate::tensor::{attention_over_cache, Mat};
use crate::trace::{PhaseTotals, SeqBatchEvent, SEQ_EVENT_BUF_CAP};

/// Calibration capture: hidden states observed at adapter insertion points.
/// Rows are samples; `to_x_matrix` transposes into the `X ∈ R^{i×k}` layout
/// of the paper's Eqn. 7.
#[derive(Default)]
pub struct Capture {
    /// Input to QKV (post-norm1), per layer: rows of dim `d_model`.
    pub qkv_in: Vec<Vec<f32>>,
    /// Input to Up/Gate (post-norm2), per layer: rows of dim `d_model`.
    pub mlp_in: Vec<Vec<f32>>,
    /// Input to Down (the MLP intermediate), per layer: rows of dim `d_hidden`.
    pub down_in: Vec<Vec<f32>>,
    pub n_layers: usize,
}

impl Capture {
    pub fn new(n_layers: usize) -> Self {
        Self {
            qkv_in: vec![Vec::new(); n_layers],
            mlp_in: vec![Vec::new(); n_layers],
            down_in: vec![Vec::new(); n_layers],
            n_layers,
        }
    }

    pub fn push(buf: &mut Vec<f32>, rows: &Mat) {
        buf.extend_from_slice(&rows.data);
    }

    /// Samples collected for layer `l` at a site, as `X: i×k` (columns are
    /// hidden states, the layout of Eqn. 7).
    pub fn x_matrix(buf: &[f32], dim: usize) -> Mat {
        let k = buf.len() / dim;
        Mat::from_vec(k, dim, buf.to_vec()).transpose()
    }
}

/// Pluggable per-layer computation.
pub trait BlockOps: Sync {
    fn config(&self) -> &ModelConfig;
    fn weights(&self) -> &ModelWeights;

    // --- sequence (GEMM) path -------------------------------------------
    fn qkv_seq(&self, layer: usize, xs: &Mat) -> (Mat, Mat, Mat);
    fn attn_out_seq(&self, layer: usize, xs: &Mat) -> Mat;
    fn mlp_seq(&self, layer: usize, xs: &Mat, cap: Option<&mut Capture>) -> Mat;

    // --- decode (GEMV) path ---------------------------------------------
    fn qkv_tok(&self, layer: usize, x: &[f32]) -> (Vec<f32>, Vec<f32>, Vec<f32>);
    fn attn_out_tok(&self, layer: usize, x: &[f32]) -> Vec<f32>;
    fn mlp_tok(&self, layer: usize, x: &[f32]) -> Vec<f32>;

    // --- batched decode path (one row per in-flight sequence) -----------
    // Defaults fall back to the per-token path row by row, so every
    // `BlockOps` implementation batches correctly out of the box; the
    // dense model and the RaNA adapters override with batched GEMM /
    // masked-GEMM kernels — that override is where iteration-level
    // batching turns into arithmetic intensity.

    fn qkv_tok_batch(&self, layer: usize, xs: &Mat) -> (Mat, Mat, Mat) {
        crate::tensor::stack3_rows(
            (0..xs.rows).map(|r| self.qkv_tok(layer, xs.row(r))).collect(),
        )
    }

    fn attn_out_tok_batch(&self, layer: usize, xs: &Mat) -> Mat {
        let rows: Vec<Vec<f32>> =
            (0..xs.rows).map(|r| self.attn_out_tok(layer, xs.row(r))).collect();
        Mat::from_rows(&rows)
    }

    fn mlp_tok_batch(&self, layer: usize, xs: &Mat) -> Mat {
        let rows: Vec<Vec<f32>> = (0..xs.rows).map(|r| self.mlp_tok(layer, xs.row(r))).collect();
        Mat::from_rows(&rows)
    }

    // --- runtime-budget batched decode ----------------------------------
    // `rates[r]` is row `r`'s compression rate; [`AMBIENT_BUDGET`] means
    // "whatever the model's ambient budget is". Defaults ignore the rates,
    // so the dense model and fixed-budget adapters are untouched; the
    // runtime-budget `AdaptedModel` overrides these to mix per-request
    // budgets inside one masked engine pass. A rate is a *scalar key*:
    // under a layer-wise allocation each layer's adapter resolves the same
    // key to its own (rank, threshold) view, so `decode_step_body` and
    // both decode batches thread per-layer budgets without carrying
    // anything more than this one f64 per row.

    fn qkv_tok_batch_budgeted(&self, layer: usize, xs: &Mat, _rates: &[f64]) -> (Mat, Mat, Mat) {
        self.qkv_tok_batch(layer, xs)
    }

    fn mlp_tok_batch_budgeted(&self, layer: usize, xs: &Mat, _rates: &[f64]) -> Mat {
        self.mlp_tok_batch(layer, xs)
    }
}

/// Per-row budget sentinel: "resolve to the model's ambient budget".
pub const AMBIENT_BUDGET: f64 = -1.0;

/// The dense (unadapted) model.
pub struct Model {
    pub cfg: ModelConfig,
    pub w: ModelWeights,
}

impl Model {
    pub fn new(cfg: ModelConfig, w: ModelWeights) -> anyhow::Result<Self> {
        w.validate(&cfg)?;
        Ok(Self { cfg, w })
    }

    pub fn load(dir: &std::path::Path) -> anyhow::Result<Self> {
        let (cfg, w) = ModelWeights::load(dir)?;
        Ok(Self { cfg, w })
    }

    fn dense_mlp_seq(&self, layer: usize, xs: &Mat, cap: Option<&mut Capture>) -> Mat {
        let l = &self.w.layers[layer];
        let mut inter = l.up.apply_seq(xs);
        let gate = l.gate.as_ref().map(|g| g.apply_seq(xs));
        ops::mlp_activate(self.cfg.arch, &mut inter, gate.as_ref());
        if let Some(cap) = cap {
            Capture::push(&mut cap.down_in[layer], &inter);
        }
        l.down.apply_seq(&inter)
    }

    fn dense_mlp_tok(&self, layer: usize, x: &[f32]) -> Vec<f32> {
        let l = &self.w.layers[layer];
        let inter: Vec<f32> = match self.cfg.arch {
            Arch::SwiGlu => {
                let up = l.up.apply(x);
                let gate = l.gate.as_ref().unwrap().apply(x);
                // Same activation books as `ops::mlp_activate` (SwiGlu: 2·h).
                measured::add(2 * up.len() as u64, 12 * up.len() as u64);
                up.iter().zip(&gate).map(|(&u, &g)| u * ops::silu(g)).collect()
            }
            Arch::GeluNeoX => {
                let up = l.up.apply(x);
                measured::add(up.len() as u64, 8 * up.len() as u64);
                up.iter().map(|&v| ops::gelu(v)).collect()
            }
        };
        l.down.apply(&inter)
    }

    fn dense_mlp_tok_batch(&self, layer: usize, xs: &Mat) -> Mat {
        let l = &self.w.layers[layer];
        let mut inter = l.up.apply_tok_batch(xs);
        let gate = l.gate.as_ref().map(|g| g.apply_tok_batch(xs));
        ops::mlp_activate(self.cfg.arch, &mut inter, gate.as_ref());
        l.down.apply_tok_batch(&inter)
    }
}

impl BlockOps for Model {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn weights(&self) -> &ModelWeights {
        &self.w
    }

    fn qkv_seq(&self, layer: usize, xs: &Mat) -> (Mat, Mat, Mat) {
        let l = &self.w.layers[layer];
        (l.wq.apply_seq(xs), l.wk.apply_seq(xs), l.wv.apply_seq(xs))
    }

    fn attn_out_seq(&self, layer: usize, xs: &Mat) -> Mat {
        self.w.layers[layer].wo.apply_seq(xs)
    }

    fn mlp_seq(&self, layer: usize, xs: &Mat, cap: Option<&mut Capture>) -> Mat {
        self.dense_mlp_seq(layer, xs, cap)
    }

    fn qkv_tok(&self, layer: usize, x: &[f32]) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let l = &self.w.layers[layer];
        (l.wq.apply(x), l.wk.apply(x), l.wv.apply(x))
    }

    fn attn_out_tok(&self, layer: usize, x: &[f32]) -> Vec<f32> {
        self.w.layers[layer].wo.apply(x)
    }

    fn mlp_tok(&self, layer: usize, x: &[f32]) -> Vec<f32> {
        self.dense_mlp_tok(layer, x)
    }

    fn qkv_tok_batch(&self, layer: usize, xs: &Mat) -> (Mat, Mat, Mat) {
        let l = &self.w.layers[layer];
        (l.wq.apply_tok_batch(xs), l.wk.apply_tok_batch(xs), l.wv.apply_tok_batch(xs))
    }

    fn attn_out_tok_batch(&self, layer: usize, xs: &Mat) -> Mat {
        self.w.layers[layer].wo.apply_tok_batch(xs)
    }

    fn mlp_tok_batch(&self, layer: usize, xs: &Mat) -> Mat {
        self.dense_mlp_tok_batch(layer, xs)
    }
}

/// Apply the arch's norm to every row.
fn norm_rows(cfg: &ModelConfig, norm: &super::weights::Norm, xs: &Mat) -> Mat {
    let mut out = Mat::zeros(xs.rows, xs.cols);
    for r in 0..xs.rows {
        let y = match cfg.arch {
            Arch::SwiGlu => ops::rmsnorm(xs.row(r), &norm.scale, cfg.norm_eps),
            Arch::GeluNeoX => ops::layernorm(
                xs.row(r),
                &norm.scale,
                norm.bias.as_ref().expect("neox norm bias"),
                cfg.norm_eps,
            ),
        };
        out.row_mut(r).copy_from_slice(&y);
    }
    out
}

pub(super) fn norm_tok(cfg: &ModelConfig, norm: &super::weights::Norm, x: &[f32]) -> Vec<f32> {
    match cfg.arch {
        Arch::SwiGlu => ops::rmsnorm(x, &norm.scale, cfg.norm_eps),
        Arch::GeluNeoX => ops::layernorm(
            x,
            &norm.scale,
            norm.bias.as_ref().expect("neox norm bias"),
            cfg.norm_eps,
        ),
    }
}

/// Full-sequence forward: returns logits `[T, vocab]`. `positions[i] = i`.
pub fn forward_seq<B: BlockOps>(b: &B, tokens: &[u32], mut cap: Option<&mut Capture>) -> Mat {
    let cfg = b.config().clone();
    let w = b.weights();
    let t = tokens.len();
    let mut xs = Mat::zeros(t, cfg.d_model);
    for (r, &tok) in tokens.iter().enumerate() {
        xs.row_mut(r).copy_from_slice(w.embed.row(tok as usize));
    }

    for layer in 0..cfg.n_layers {
        let lw = &w.layers[layer];
        let h1 = norm_rows(&cfg, &lw.norm1, &xs);
        if let Some(cap) = cap.as_deref_mut() {
            Capture::push(&mut cap.qkv_in[layer], &h1);
        }
        let (mut q, mut k, v) = b.qkv_seq(layer, &h1);
        for r in 0..t {
            ops::rope_heads(q.row_mut(r), cfg.n_heads, r, cfg.rope_theta);
            ops::rope_heads(k.row_mut(r), cfg.n_heads, r, cfg.rope_theta);
        }
        let attn = ops::causal_attention_seq(&q, &k, &v, cfg.n_heads);
        let attn_o = b.attn_out_seq(layer, &attn);

        match cfg.arch {
            Arch::SwiGlu => {
                // Sequential residual: x += attn; x += mlp(norm2(x)).
                for i in 0..xs.data.len() {
                    xs.data[i] += attn_o.data[i];
                }
                let h2 = norm_rows(&cfg, &lw.norm2, &xs);
                if let Some(cap) = cap.as_deref_mut() {
                    Capture::push(&mut cap.mlp_in[layer], &h2);
                }
                let m = b.mlp_seq(layer, &h2, cap.as_deref_mut());
                for i in 0..xs.data.len() {
                    xs.data[i] += m.data[i];
                }
            }
            Arch::GeluNeoX => {
                // Parallel residual: x += attn(norm1(x)) + mlp(norm2(x)).
                let h2 = norm_rows(&cfg, &lw.norm2, &xs);
                if let Some(cap) = cap.as_deref_mut() {
                    Capture::push(&mut cap.mlp_in[layer], &h2);
                }
                let m = b.mlp_seq(layer, &h2, cap.as_deref_mut());
                for i in 0..xs.data.len() {
                    xs.data[i] += attn_o.data[i] + m.data[i];
                }
            }
        }
    }

    let hf = norm_rows(&cfg, &w.final_norm, &xs);
    hf.matmul(&w.lm_head.wt)
}

/// KV cache for incremental decoding.
pub struct KvCache {
    k: Vec<Mat>,
    v: Vec<Mat>,
    len: usize,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig) -> Self {
        Self {
            k: (0..cfg.n_layers).map(|_| Mat::zeros(cfg.max_seq, cfg.d_model)).collect(),
            v: (0..cfg.n_layers).map(|_| Mat::zeros(cfg.max_seq, cfg.d_model)).collect(),
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn clear(&mut self) {
        self.len = 0;
    }

    /// Roll the cache back to `len` tokens (speculative rollback): rows
    /// past the new end are logically discarded — the next append at that
    /// position simply overwrites them.
    pub fn truncate(&mut self, len: usize) {
        assert!(len <= self.len, "truncate cannot extend ({} -> {len})", self.len);
        self.len = len;
    }
}

/// One decode step: append `token` at position `cache.len()`, return logits.
///
/// A sequence at the model's positional capacity yields a typed
/// [`CacheError::CacheFull`] (not a panic): callers retire the sequence and
/// keep serving.
pub fn decode_step<B: BlockOps>(
    b: &B,
    token: u32,
    cache: &mut KvCache,
) -> Result<Vec<f32>, CacheError> {
    let cfg = b.config().clone();
    let w = b.weights();
    let pos = cache.len;
    if pos >= cfg.max_seq {
        return Err(CacheError::CacheFull { seq: 0, pos, capacity: cfg.max_seq });
    }
    let mut x: Vec<f32> = w.embed.row(token as usize).to_vec();

    for layer in 0..cfg.n_layers {
        let lw = &w.layers[layer];
        let h1 = norm_tok(&cfg, &lw.norm1, &x);
        let (mut q, mut k, v) = b.qkv_tok(layer, &h1);
        ops::rope_heads(&mut q, cfg.n_heads, pos, cfg.rope_theta);
        ops::rope_heads(&mut k, cfg.n_heads, pos, cfg.rope_theta);
        cache.k[layer].row_mut(pos).copy_from_slice(&k);
        cache.v[layer].row_mut(pos).copy_from_slice(&v);

        // Attend over rows 0..=pos of the cache.
        let attn = attention_over_cache(&q, &cache.k[layer], &cache.v[layer], pos + 1, cfg.n_heads);
        let attn_o = b.attn_out_tok(layer, &attn);

        match cfg.arch {
            Arch::SwiGlu => {
                for i in 0..x.len() {
                    x[i] += attn_o[i];
                }
                let h2 = norm_tok(&cfg, &lw.norm2, &x);
                let m = b.mlp_tok(layer, &h2);
                for i in 0..x.len() {
                    x[i] += m[i];
                }
            }
            Arch::GeluNeoX => {
                let h2 = norm_tok(&cfg, &lw.norm2, &x);
                let m = b.mlp_tok(layer, &h2);
                for i in 0..x.len() {
                    x[i] += attn_o[i] + m[i];
                }
            }
        }
    }
    cache.len = pos + 1;

    let hf = norm_tok(&cfg, &w.final_norm, &x);
    Ok(w.lm_head.apply(&hf))
}

/// One **batched** decode step: row `r` of `tokens`/`caches` is an
/// independent sequence whose token is appended at its own position
/// `caches[r].len()` (positions may be ragged). Returns logits
/// `[N, vocab]`.
///
/// Row `r` computes exactly what `decode_step` would for that sequence —
/// the sequential path stays the oracle the batched path is tested
/// against — but the linear layers run as batched GEMMs / masked GEMMs
/// across all rows, which is where batch size buys arithmetic intensity.
pub fn decode_step_batch<B: BlockOps>(
    b: &B,
    tokens: &[u32],
    caches: &mut [&mut KvCache],
) -> Result<Mat, CacheError> {
    decode_step_batch_inner(b, tokens, caches, None)
}

/// [`decode_step_batch`] with a per-row compute budget: `rates[r]` is row
/// `r`'s compression rate ([`AMBIENT_BUDGET`] = the model's ambient). Rows
/// at different budgets share every batched kernel via per-row rank masks;
/// each row's logits are bit-identical to a uniform-budget pass at its own
/// rate (the §2a row-independence contract extended to budgets).
pub fn decode_step_batch_budgeted<B: BlockOps>(
    b: &B,
    tokens: &[u32],
    caches: &mut [&mut KvCache],
    rates: &[f64],
) -> Result<Mat, CacheError> {
    assert_eq!(tokens.len(), rates.len(), "decode_step_batch_budgeted arity");
    decode_step_batch_inner(b, tokens, caches, Some(rates))
}

fn decode_step_batch_inner<B: BlockOps>(
    b: &B,
    tokens: &[u32],
    caches: &mut [&mut KvCache],
    rates: Option<&[f64]>,
) -> Result<Mat, CacheError> {
    assert_eq!(tokens.len(), caches.len(), "decode_step_batch arity");
    let rows: Vec<(usize, u32)> = tokens.iter().copied().enumerate().collect();
    decode_step_batch_multi(b, &rows, caches, rates)
}

/// One batched decode pass where a cache may receive **several successive
/// tokens** — the speculative verify window. `rows[r] = (ci, token)` feeds
/// `token` to `caches[ci]`; a cache's rows must appear in stream order, so
/// row `r` lands at position `caches[ci].len() + (rows of ci before r)`.
///
/// Within one pass, a later row of a sequence attends over the K/V rows
/// the sequence's earlier rows just wrote (the per-layer body visits rows
/// in order), and every linear kernel on this path is row-independent — so
/// each row computes **bit-for-bit** what the same token fed one pass at a
/// time would (the §2a contract extended to multi-token rows). That is
/// what makes speculative verification exact by construction. `rates` is
/// per **row**. Errors are typed and pre-mutation: `seq` names the
/// offending row.
pub fn decode_step_batch_multi<B: BlockOps>(
    b: &B,
    rows: &[(usize, u32)],
    caches: &mut [&mut KvCache],
    rates: Option<&[f64]>,
) -> Result<Mat, CacheError> {
    let cfg = b.config().clone();
    let mut counts = vec![0usize; caches.len()];
    let mut positions = Vec::with_capacity(rows.len());
    for &(ci, _) in rows {
        let pos = caches[ci].len + counts[ci];
        if pos >= cfg.max_seq {
            // Typed, pre-state-mutation: no cache has been written yet, so
            // the caller can drop the offending sequence and retry.
            return Err(CacheError::CacheFull { seq: positions.len(), pos, capacity: cfg.max_seq });
        }
        positions.push(pos);
        counts[ci] += 1;
    }
    let tokens: Vec<u32> = rows.iter().map(|&(_, t)| t).collect();

    let n_heads = cfg.n_heads;
    let logits = decode_step_body(b, &tokens, &positions, rates, |layer, r, q, k, v| {
        let pos = positions[r];
        let cache = &mut *caches[rows[r].0];
        cache.k[layer].row_mut(pos).copy_from_slice(k);
        cache.v[layer].row_mut(pos).copy_from_slice(v);
        attention_over_cache(q, &cache.k[layer], &cache.v[layer], pos + 1, n_heads)
    });
    for (ci, cache) in caches.iter_mut().enumerate() {
        cache.len += counts[ci];
    }
    Ok(logits)
}

/// Shared per-layer body of one batched decode step, generic over the KV
/// layout: `append_attend(layer, r, q, k, v)` commits row `r`'s (already
/// roped) K/V at its position and returns its attention output. The dense
/// ([`decode_step_batch`]) and paged (`decode_step_paged`) paths both run
/// exactly this code, so their logits agree **bit-for-bit** by
/// construction — only cache addressing differs (the §2a/§2b determinism
/// contract).
pub(super) fn decode_step_body<B: BlockOps>(
    b: &B,
    tokens: &[u32],
    positions: &[usize],
    rates: Option<&[f64]>,
    mut append_attend: impl FnMut(usize, usize, &[f32], &[f32], &[f32]) -> Vec<f32>,
) -> Mat {
    let cfg = b.config().clone();
    let w = b.weights();
    let n = tokens.len();
    let mut xs = Mat::zeros(n, cfg.d_model);
    for (r, &tok) in tokens.iter().enumerate() {
        xs.row_mut(r).copy_from_slice(w.embed.row(tok as usize));
    }

    // Per-layer measured-FLOP attribution: diff the process-global counter
    // around each layer (and the lm-head tail). Off the compute path — the
    // arithmetic below is identical whether or not counters are enabled.
    let track = measured::enabled();
    let mut f_prev = if track { measured::flops_now() } else { 0 };

    for layer in 0..cfg.n_layers {
        let lw = &w.layers[layer];
        let mut h1 = Mat::zeros(n, cfg.d_model);
        for r in 0..n {
            h1.row_mut(r).copy_from_slice(&norm_tok(&cfg, &lw.norm1, xs.row(r)));
        }
        let (mut q, mut k, v) = match rates {
            Some(rates) => b.qkv_tok_batch_budgeted(layer, &h1, rates),
            None => b.qkv_tok_batch(layer, &h1),
        };
        let mut attn = Mat::zeros(n, cfg.d_model);
        for r in 0..n {
            let pos = positions[r];
            ops::rope_heads(q.row_mut(r), cfg.n_heads, pos, cfg.rope_theta);
            ops::rope_heads(k.row_mut(r), cfg.n_heads, pos, cfg.rope_theta);
            let a = append_attend(layer, r, q.row(r), k.row(r), v.row(r));
            attn.row_mut(r).copy_from_slice(&a);
        }
        let attn_o = b.attn_out_tok_batch(layer, &attn);

        match cfg.arch {
            Arch::SwiGlu => {
                for i in 0..xs.data.len() {
                    xs.data[i] += attn_o.data[i];
                }
                let mut h2 = Mat::zeros(n, cfg.d_model);
                for r in 0..n {
                    h2.row_mut(r).copy_from_slice(&norm_tok(&cfg, &lw.norm2, xs.row(r)));
                }
                let m = match rates {
                    Some(rates) => b.mlp_tok_batch_budgeted(layer, &h2, rates),
                    None => b.mlp_tok_batch(layer, &h2),
                };
                for i in 0..xs.data.len() {
                    xs.data[i] += m.data[i];
                }
            }
            Arch::GeluNeoX => {
                let mut h2 = Mat::zeros(n, cfg.d_model);
                for r in 0..n {
                    h2.row_mut(r).copy_from_slice(&norm_tok(&cfg, &lw.norm2, xs.row(r)));
                }
                let m = match rates {
                    Some(rates) => b.mlp_tok_batch_budgeted(layer, &h2, rates),
                    None => b.mlp_tok_batch(layer, &h2),
                };
                for i in 0..xs.data.len() {
                    xs.data[i] += attn_o.data[i] + m.data[i];
                }
            }
        }
        if track {
            let now = measured::flops_now();
            measured::add_layer(layer, now.saturating_sub(f_prev));
            f_prev = now;
        }
    }

    let mut hf = Mat::zeros(n, cfg.d_model);
    for r in 0..n {
        hf.row_mut(r).copy_from_slice(&norm_tok(&cfg, &w.final_norm, xs.row(r)));
    }
    let logits = w.lm_head.apply_tok_batch(&hf);
    if track {
        // Pseudo-layer `n_layers` books the lm-head (plus the uncounted
        // final norm, which contributes zero by convention).
        measured::add_layer(cfg.n_layers, measured::flops_now().saturating_sub(f_prev));
    }
    logits
}

/// Everything one decode sequence needs beyond its prompt: how many tokens
/// to generate, how to pick them, and (optionally) at what compute budget.
/// The greedy default reproduces the pre-sampler decode bit-for-bit.
#[derive(Clone, Debug)]
pub struct SeqSpec {
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub sampling: ops::Sampling,
    /// Per-sequence compression-rate override; `None` = the model's
    /// ambient budget.
    pub budget: Option<f64>,
    /// Per-sequence speculative draft length: `None` = the batch default
    /// ([`crate::spec::SpecConfig::default_k`]), `Some(0)` = explicitly
    /// off, `Some(k)` = draft up to `k` tokens per round.
    pub spec_k: Option<usize>,
    /// Scheduling annotation (priority/deadline/tenant) carried from the
    /// wire protocol for observability — never read by the decode
    /// schedule, so it cannot perturb any determinism pin.
    pub sched: crate::sched::SchedClass,
}

impl SeqSpec {
    pub fn greedy(prompt: Vec<u32>, max_new: usize) -> Self {
        Self {
            prompt,
            max_new,
            sampling: ops::Sampling::default(),
            budget: None,
            spec_k: None,
            sched: crate::sched::SchedClass::default(),
        }
    }
}

/// Per-sequence speculative-decoding state: the adaptive draft-length
/// controller plus a corrected token from a rejected round that has been
/// sampled and emitted but still needs its full-budget engine pass.
pub(super) struct SpecSeq {
    pub(super) ctrl: crate::spec::DraftController,
    pub(super) pending: Option<u32>,
}

impl SpecSeq {
    pub(super) fn for_join(cfg: &crate::spec::SpecConfig, spec_k: Option<usize>) -> Option<Self> {
        let k = cfg.resolve_k(spec_k);
        (k > 0).then(|| SpecSeq {
            ctrl: crate::spec::DraftController::new(k),
            pending: None,
        })
    }
}

/// State of one in-flight sequence in a [`DecodeBatch`].
struct SeqState {
    id: u64,
    prompt: Vec<u32>,
    /// How many prompt tokens have been fed into the cache so far.
    fed: usize,
    n_gen: usize,
    sampling: ops::Sampling,
    rng: crate::util::rng::Xoshiro256,
    budget: Option<f64>,
    /// Speculative decoding state (`None` = plain decoding).
    spec: Option<SpecSeq>,
    generated: Vec<u32>,
    last_logits: Vec<f32>,
    cache: KvCache,
    done: bool,
    /// Measured FLOPs attributed to this sequence (its share of every
    /// engine pass it rode, split proportionally by row count).
    flops: u64,
}

/// A retired sequence returned by [`DecodeBatch::retire_finished`].
pub struct FinishedSeq {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub generated: Vec<u32>,
    /// Measured FLOPs attributed to this sequence over its lifetime.
    pub flops: u64,
}

/// Iteration-level batched greedy decoder: up to `capacity` in-flight
/// sequences, each with its own [`KvCache`] slot, advance **one token per
/// engine pass** through [`decode_step_batch`]. Sequences join and retire
/// *between steps* (continuous batching), and ragged prefill shares engine
/// passes with neighbours that are already decoding: a sequence's per-step
/// token is its next prompt token until the prompt is exhausted, then the
/// greedy argmax of its previous logits.
///
/// Determinism: every batched kernel on this path accumulates each output
/// element in the same ascending order as the single-row GEMV path, so a
/// sequence's tokens are identical regardless of batch size or of which
/// other sequences share the batch.
pub struct DecodeBatch {
    cfg: ModelConfig,
    slots: Vec<Option<SeqState>>,
    next_id: u64,
    /// Tokens generated since the last [`DecodeBatch::drain_emitted`]
    /// (streaming surface: the serving layer turns these into frames).
    emitted: Vec<(u64, u32)>,
    /// Speculation defaults (draft length, draft budget) for joins.
    spec: crate::spec::SpecConfig,
    /// Prompt tokens fed per sequence per engine pass (chunked prefill,
    /// DESIGN.md §2h). 1 = the legacy one-token-per-pass interleave; larger
    /// chunks cut a length-L prefill from L passes to ⌈L/C⌉ while the
    /// multi-row pass keeps the outputs bitwise identical.
    prefill_chunk: usize,
    /// Tokens fed across all steps (batch-occupancy accounting; committed
    /// tokens only — rolled-back draft/verify rows are not counted here).
    pub tokens_processed: u64,
    /// Engine passes executed (steps where at least one sequence advanced).
    pub steps: u64,
    /// Draft tokens proposed by speculation rounds.
    pub draft_tokens: u64,
    /// Draft tokens that survived full-budget verification.
    pub accepted_tokens: u64,
    /// Speculation rounds that rolled the cache back (some draft rejected).
    pub spec_rollbacks: u64,
    /// Wall-clock split of the engine passes (timing only — never read by
    /// the schedule).
    phases: PhaseTotals,
    /// Measured FLOP/byte split of the engine passes, attributed to phases
    /// by the same row-kind rule as `phases` (observability only).
    flops: FlopPhases,
    /// Structural per-sequence events since the last drain (prefill chunks,
    /// settled speculation rounds), bounded by [`SEQ_EVENT_BUF_CAP`].
    seq_events: Vec<(u64, SeqBatchEvent)>,
}

impl DecodeBatch {
    pub fn new(cfg: &ModelConfig, capacity: usize) -> Self {
        Self {
            cfg: cfg.clone(),
            slots: (0..capacity.max(1)).map(|_| None).collect(),
            next_id: 0,
            emitted: Vec::new(),
            spec: crate::spec::SpecConfig::default(),
            prefill_chunk: 1,
            tokens_processed: 0,
            steps: 0,
            draft_tokens: 0,
            accepted_tokens: 0,
            spec_rollbacks: 0,
            phases: PhaseTotals::default(),
            flops: FlopPhases::default(),
            seq_events: Vec::new(),
        }
    }

    /// Configure speculation defaults for sequences joined from now on.
    pub fn set_spec(&mut self, spec: crate::spec::SpecConfig) {
        self.spec = spec;
    }

    /// Prompt tokens fed per sequence per engine pass (clamped to ≥ 1).
    /// Chunked and monolithic prefill are bitwise-equivalent — the chunk
    /// size only trades passes-to-first-token against per-pass latency for
    /// the decode rows sharing the pass.
    pub fn set_prefill_chunk(&mut self, chunk: usize) {
        self.prefill_chunk = chunk.max(1);
    }

    /// `(draft_tokens, accepted_tokens, spec_rollbacks)` running totals.
    pub fn spec_stats(&self) -> (u64, u64, u64) {
        (self.draft_tokens, self.accepted_tokens, self.spec_rollbacks)
    }

    /// Running per-phase wall-clock totals (sessions report deltas upward).
    pub fn phase_stats(&self) -> PhaseTotals {
        self.phases
    }

    /// Running per-phase measured FLOP/byte totals (sessions report deltas
    /// upward, mirroring [`DecodeBatch::phase_stats`]).
    pub fn flop_stats(&self) -> FlopPhases {
        self.flops
    }

    /// Structural per-sequence events since the last drain.
    pub fn drain_seq_events(&mut self) -> Vec<(u64, SeqBatchEvent)> {
        std::mem::take(&mut self.seq_events)
    }

    /// Put drained-but-foreign events back at the front (shared-batch
    /// sessions return other sessions' events, like
    /// [`DecodeBatch::restore_emitted`]).
    pub fn restore_seq_events(&mut self, mut items: Vec<(u64, SeqBatchEvent)>) {
        items.extend(std::mem::take(&mut self.seq_events));
        self.seq_events = items;
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Sequences currently occupying a slot (including finished-but-not-
    /// yet-retired ones).
    pub fn active(&self) -> usize {
        self.slots.iter().flatten().count()
    }

    /// True while any in-flight sequence still has tokens to process.
    pub fn has_work(&self) -> bool {
        self.slots.iter().flatten().any(|s| !s.done)
    }

    /// Admit a sequence into a free slot; returns its id, or `None` when
    /// every slot is occupied. Up to `n_gen` tokens are greedily decoded
    /// after the prompt (fewer if the KV cache fills first, matching
    /// `eval::greedy_decode`'s cap).
    pub fn try_join(&mut self, prompt: Vec<u32>, n_gen: usize) -> Option<u64> {
        self.try_join_spec(SeqSpec::greedy(prompt, n_gen))
    }

    /// Admit a sequence with explicit sampling params and budget override.
    pub fn try_join_spec(&mut self, spec: SeqSpec) -> Option<u64> {
        let speculation = SpecSeq::for_join(&self.spec, spec.spec_k);
        let slot = self.slots.iter_mut().find(|s| s.is_none())?;
        let id = self.next_id;
        self.next_id += 1;
        // An empty prompt yields no logits to decode from: born finished.
        let done = spec.prompt.is_empty();
        *slot = Some(SeqState {
            id,
            prompt: spec.prompt,
            fed: 0,
            n_gen: spec.max_new,
            rng: crate::util::rng::Xoshiro256::new(spec.sampling.seed),
            sampling: spec.sampling,
            budget: spec.budget,
            spec: speculation,
            generated: Vec::new(),
            last_logits: Vec::new(),
            cache: KvCache::new(&self.cfg),
            done,
            flops: 0,
        });
        Some(id)
    }

    /// Mark a sequence finished where it stands (client cancel); its
    /// partial result is returned by the next
    /// [`DecodeBatch::retire_finished`]. Returns false for unknown ids.
    pub fn cancel(&mut self, id: u64) -> bool {
        for s in self.slots.iter_mut().flatten() {
            if s.id == id {
                s.done = true;
                return true;
            }
        }
        false
    }

    /// Tokens generated since the last drain, in generation order — the
    /// incremental stream the serving layer frames to clients.
    pub fn drain_emitted(&mut self) -> Vec<(u64, u32)> {
        std::mem::take(&mut self.emitted)
    }

    /// Put drained-but-unconsumed tokens back at the front of the stream
    /// (a session on a shared batch returns other sessions' deltas).
    pub fn restore_emitted(&mut self, mut items: Vec<(u64, u32)>) {
        items.extend(std::mem::take(&mut self.emitted));
        self.emitted = items;
    }

    /// One engine pass: every live sequence contributes its next token —
    /// and, when speculation is on for a generation-phase sequence, a
    /// whole draft/verify round (DESIGN.md §2d):
    ///
    /// 1. draft `k` tokens at the low draft budget (batched across spec
    ///    sequences),
    /// 2. roll the draft KV back ([`KvCache::truncate`]),
    /// 3. verify `x0, d_1..d_k` in ONE full-budget pass shared with every
    ///    plain/prefill row ([`decode_step_batch_multi`]),
    /// 4. commit the accepted prefix and roll back the rest.
    ///
    /// Greedy speculative text is bit-identical to non-speculative decode;
    /// sampled text is distribution-identical (see `crate::spec`).
    ///
    /// Returns how many sequences advanced (0 = nothing left to do; call
    /// [`DecodeBatch::retire_finished`] to free the slots).
    pub fn step<B: BlockOps>(&mut self, b: &B) -> usize {
        let max_seq = self.cfg.max_seq;

        // --- 1. Token selection (the schedule is unchanged: speculation
        // only changes HOW a generation-phase token is fed, never which
        // token is selected; chunking only changes how many *prompt* rows
        // one pass carries). `k > 0` marks a speculation round; `base` is
        // the rollback target.
        struct Plan {
            idx: usize,
            /// Tokens this sequence feeds this pass: one prefill chunk
            /// (stream order) or a single generation-phase token.
            toks: Vec<u32>,
            k: usize,
            base: usize,
            /// Prompt-feed row (timing attribution only).
            prefill: bool,
        }
        let mut plan: Vec<Plan> = Vec::new();
        for idx in 0..self.slots.len() {
            let Some(s) = self.slots[idx].as_mut() else { continue };
            if s.done {
                continue;
            }
            if s.cache.len() >= max_seq {
                // Over-long prompt: truncate prefill rather than overflow.
                s.done = true;
                continue;
            }
            let (toks, gen_phase) = if s.fed < s.prompt.len() {
                // Prefill chunk: up to `prefill_chunk` prompt tokens in one
                // pass, clamped to the remaining prompt and the positional
                // capacity (cache.len() < max_seq was checked above).
                let chunk = self
                    .prefill_chunk
                    .min(s.prompt.len() - s.fed)
                    .min(max_seq - s.cache.len())
                    .max(1);
                let toks = s.prompt[s.fed..s.fed + chunk].to_vec();
                s.fed += chunk;
                if self.seq_events.len() < SEQ_EVENT_BUF_CAP {
                    self.seq_events
                        .push((s.id, SeqBatchEvent::Prefill { tokens: chunk as u32 }));
                }
                (toks, false)
            } else if let Some(c) = s.spec.as_mut().and_then(|sp| sp.pending.take()) {
                // Corrected token from a rejected round: sampled and
                // emitted last pass, still owed its full-budget KV.
                (vec![c], true)
            } else if s.generated.len() >= s.n_gen {
                s.done = true; // n_gen == 0, or finished last step
                continue;
            } else if s.cache.len() + 1 >= max_seq {
                s.done = true; // same cap as greedy_decode
                continue;
            } else {
                let next = ops::sample_token(&s.last_logits, &s.sampling, &mut s.rng);
                s.generated.push(next);
                self.emitted.push((s.id, next));
                if s.generated.len() >= s.n_gen {
                    // Final token: recorded, but needs no engine pass.
                    s.done = true;
                    continue;
                }
                (vec![next], true)
            };
            // Draft length: the controller's pick, clamped so accepted
            // drafts can neither exceed the request nor the positional
            // capacity. Plain decode refuses to sample once
            // `len + 1 >= max_seq`, so draft d_i (sampled at len base + i)
            // is only emittable while `base + i + 1 < max_seq`: k caps at
            // `max_seq - base - 2` — one tighter than the feed capacity —
            // or the speculative stream would outrun the plain one at the
            // cache boundary.
            let k = if gen_phase {
                s.spec
                    .as_ref()
                    .map(|sp| {
                        sp.ctrl
                            .k()
                            .min(s.n_gen.saturating_sub(s.generated.len()))
                            .min(max_seq.saturating_sub(s.cache.len() + 2))
                    })
                    .unwrap_or(0)
            } else {
                0
            };
            plan.push(Plan { idx, toks, k, base: s.cache.len(), prefill: !gen_phase });
        }

        // --- 2. Draft phase: k low-budget passes batched across the
        // speculating sequences; pass j feeds x0 (j = 0) or d_j and its
        // logits propose d_{j+1}.
        let mut drafts: Vec<Vec<u32>> = (0..plan.len()).map(|_| Vec::new()).collect();
        let mut dists: Vec<crate::spec::DraftDists> =
            (0..plan.len()).map(|_| Vec::new()).collect();
        if plan.iter().any(|p| p.k > 0) {
            let t_draft = std::time::Instant::now();
            let f_draft0 = measured::enabled().then(measured::snapshot);
            let draft_rate = self.spec.draft_rate;
            let mut j = 0;
            loop {
                let active: Vec<usize> = (0..plan.len()).filter(|&p| plan[p].k > j).collect();
                if active.is_empty() {
                    break;
                }
                let tokens: Vec<u32> = active
                    .iter()
                    // k > 0 only on generation-phase rows, whose `toks` is
                    // the single token x0 the draft round starts from.
                    .map(|&p| if j == 0 { plan[p].toks[0] } else { drafts[p][j - 1] })
                    .collect();
                let rates: Vec<f64> = vec![draft_rate; active.len()];
                let res = {
                    let mut caches: Vec<&mut KvCache> = Vec::with_capacity(active.len());
                    let mut want = active.iter().map(|&p| plan[p].idx).peekable();
                    for (idx, slot) in self.slots.iter_mut().enumerate() {
                        if want.peek() == Some(&idx) {
                            want.next();
                            caches
                                .push(&mut slot.as_mut().expect("planned slot occupied").cache);
                        }
                    }
                    decode_step_batch_inner(b, &tokens, &mut caches, Some(&rates))
                };
                let logits = match res {
                    Ok(l) => l,
                    Err(e) => {
                        // Unreachable given the clamps above; degrade the
                        // offending sequence to the drafts it already has.
                        let p = active[e.seq().min(active.len() - 1)];
                        plan[p].k = drafts[p].len();
                        continue;
                    }
                };
                for (r, &p) in active.iter().enumerate() {
                    let s = self.slots[plan[p].idx].as_mut().expect("planned slot occupied");
                    let row = logits.row(r);
                    let d = ops::sample_token(row, &s.sampling, &mut s.rng);
                    if !s.sampling.is_greedy() {
                        dists[p].push(ops::sampling_dist(row, &s.sampling));
                    }
                    drafts[p].push(d);
                }
                j += 1;
            }
            // Roll every draft append back: draft KV is low-budget KV and
            // must never seed a full-budget context.
            for p in &plan {
                if p.k > 0 {
                    let s = self.slots[p.idx].as_mut().expect("planned slot occupied");
                    s.cache.truncate(p.base);
                }
            }
            self.phases.spec_draft_us += t_draft.elapsed().as_micros() as u64;
            if let Some(base) = f_draft0 {
                // Draft-phase measured compute; per-sequence shares split
                // proportionally by draft length (u128 to avoid overflow).
                let delta = measured::snapshot().delta_since(&base);
                self.flops.draft += delta;
                let total_k: u64 = plan.iter().map(|p| p.k as u64).sum();
                if total_k > 0 && delta.flops > 0 {
                    for p in &plan {
                        if p.k == 0 {
                            continue;
                        }
                        let share =
                            (delta.flops as u128 * p.k as u128 / total_k as u128) as u64;
                        if let Some(s) = self.slots[p.idx].as_mut() {
                            s.flops += share;
                        }
                    }
                }
            }
        }

        // --- 3. One full-budget pass over all rows: plain/prefill rows
        // feed one token, speculating rows feed x0 + their drafts.
        let t_pass = std::time::Instant::now();
        let f_pass0 = measured::enabled().then(measured::snapshot);
        let logits = loop {
            if plan.is_empty() {
                return 0;
            }
            let mut rows: Vec<(usize, u32)> = Vec::new();
            for (ci, p) in plan.iter().enumerate() {
                for &t in &p.toks {
                    rows.push((ci, t));
                }
                for &d in &drafts[ci][..p.k] {
                    rows.push((ci, d));
                }
            }
            // Per-row budgets only when some sequence carries an override;
            // the all-ambient batch keeps the legacy unbudgeted call.
            let rates: Option<Vec<f64>> = plan
                .iter()
                .any(|p| {
                    self.slots[p.idx].as_ref().is_some_and(|s| s.budget.is_some())
                })
                .then(|| {
                    rows.iter()
                        .map(|&(ci, _)| {
                            self.slots[plan[ci].idx]
                                .as_ref()
                                .and_then(|s| s.budget)
                                .unwrap_or(AMBIENT_BUDGET)
                        })
                        .collect()
                });
            let res = {
                let mut caches: Vec<&mut KvCache> = Vec::with_capacity(plan.len());
                let mut want = plan.iter().map(|p| p.idx).peekable();
                for (idx, slot) in self.slots.iter_mut().enumerate() {
                    if want.peek() == Some(&idx) {
                        want.next();
                        caches.push(&mut slot.as_mut().expect("planned slot occupied").cache);
                    }
                }
                decode_step_batch_multi(b, &rows, &mut caches, rates.as_deref())
            };
            match res {
                Ok(l) => break l,
                Err(e) => {
                    // Unreachable given the pre-guards above, but the
                    // contract stands: a full sequence retires; the rest of
                    // the pass proceeds.
                    let row = e.seq().min(rows.len() - 1);
                    let ci = rows[row].0;
                    self.slots[plan[ci].idx].as_mut().expect("planned slot occupied").done =
                        true;
                    plan.remove(ci);
                    drafts.remove(ci);
                    dists.remove(ci);
                }
            }
        };
        {
            // Split the shared pass across prefill / decode / verify rows by
            // row count — timing attribution only, no compute branch.
            let pass_us = t_pass.elapsed().as_micros() as u64;
            let prefill_rows: u64 =
                plan.iter().filter(|p| p.prefill).map(|p| p.toks.len() as u64).sum();
            let verify_rows: u64 = plan.iter().map(|p| p.k as u64).sum();
            let decode_rows = plan.iter().filter(|p| !p.prefill).count() as u64;
            self.phases.attribute_pass(pass_us, prefill_rows, decode_rows, verify_rows);
            if let Some(base) = f_pass0 {
                // Measured compute of the shared pass: same row-kind split
                // as the timing above, plus per-sequence shares by row count.
                let delta = measured::snapshot().delta_since(&base);
                self.flops.attribute_pass(delta, prefill_rows, decode_rows, verify_rows);
                let total_rows: u64 =
                    plan.iter().map(|p| (p.toks.len() + p.k) as u64).sum();
                if total_rows > 0 && delta.flops > 0 {
                    for p in &plan {
                        let share = (delta.flops as u128
                            * (p.toks.len() + p.k) as u128
                            / total_rows as u128) as u64;
                        if let Some(s) = self.slots[p.idx].as_mut() {
                            s.flops += share;
                        }
                    }
                }
            }
        }

        // --- 4. Record logits; accept/roll back speculation rounds.
        let mut committed = 0u64;
        let mut cursor = 0usize;
        for (ci, p) in plan.iter().enumerate() {
            let s = self.slots[p.idx].as_mut().expect("planned slot occupied");
            if p.k == 0 {
                // The held logits are the final fed row's — for a prefill
                // chunk that is the logits after its last prompt token,
                // exactly what feeding the chunk one pass at a time (or a
                // monolithic prefill) would have held.
                s.last_logits = logits.row(cursor + p.toks.len() - 1).to_vec();
                committed += p.toks.len() as u64;
                cursor += p.toks.len();
                continue;
            }
            let verify: Vec<&[f32]> = (0..=p.k).map(|i| logits.row(cursor + i)).collect();
            let out = crate::spec::accept_drafts(
                &drafts[ci][..p.k],
                &dists[ci],
                &verify,
                &s.sampling,
                &mut s.rng,
            );
            let a = out.accepted;
            self.draft_tokens += p.k as u64;
            self.accepted_tokens += a as u64;
            if self.seq_events.len() < SEQ_EVENT_BUF_CAP {
                self.seq_events.push((
                    s.id,
                    SeqBatchEvent::SpecRound { drafted: p.k as u32, accepted: a as u32 },
                ));
            }
            committed += 1 + a as u64;
            for &d in &drafts[ci][..a] {
                s.generated.push(d);
                self.emitted.push((s.id, d));
            }
            if a < p.k {
                // Rejected tail: roll the cache back to the accepted
                // prefix; the target logits at the first rejected position
                // become the held logits, exactly as plain decoding would
                // hold them.
                self.spec_rollbacks += 1;
                s.cache.truncate(p.base + 1 + a);
                s.last_logits = logits.row(cursor + a).to_vec();
                if s.generated.len() >= s.n_gen || s.cache.len() + 1 >= max_seq {
                    s.done = true;
                } else {
                    let c = out.corrected.expect("rejection carries a corrected token");
                    s.generated.push(c);
                    self.emitted.push((s.id, c));
                    if s.generated.len() >= s.n_gen {
                        s.done = true;
                    } else {
                        s.spec.as_mut().expect("speculating sequence").pending = Some(c);
                    }
                }
            } else {
                // Full acceptance: the bonus row V_k is the next held
                // logits (the standard free token).
                s.last_logits = logits.row(cursor + p.k).to_vec();
                if s.generated.len() >= s.n_gen {
                    s.done = true;
                }
            }
            if let Some(sp) = s.spec.as_mut() {
                sp.ctrl.observe(p.k, a);
            }
            cursor += 1 + p.k;
        }
        let n = plan.len();
        self.steps += 1;
        self.tokens_processed += committed;
        n
    }

    /// Remove finished sequences, freeing their slots for new joins.
    pub fn retire_finished(&mut self) -> Vec<FinishedSeq> {
        let mut out = Vec::new();
        for slot in &mut self.slots {
            if slot.as_ref().map(|s| s.done).unwrap_or(false) {
                let s = slot.take().expect("checked above");
                out.push(FinishedSeq {
                    id: s.id,
                    prompt: s.prompt,
                    generated: s.generated,
                    flops: s.flops,
                });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::PythiaSize;

    fn tiny_cfg(arch: Arch) -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            arch,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_hidden: 32,
            vocab: 64,
            max_seq: 32,
            rope_theta: 10_000.0,
            norm_eps: 1e-5,
        }
    }

    fn tiny_model(arch: Arch) -> Model {
        let cfg = tiny_cfg(arch);
        let w = ModelWeights::random_init(&cfg, 11);
        Model::new(cfg, w).unwrap()
    }

    #[test]
    fn decode_matches_seq_forward_swiglu() {
        let m = tiny_model(Arch::SwiGlu);
        let tokens: Vec<u32> = vec![1, 5, 9, 30, 2, 17];
        let seq_logits = forward_seq(&m, &tokens, None);
        let mut cache = KvCache::new(&m.cfg);
        for (i, &t) in tokens.iter().enumerate() {
            let logits = decode_step(&m, t, &mut cache).unwrap();
            crate::util::prop::close_slices(&logits, seq_logits.row(i), 2e-4, 2e-4)
                .unwrap_or_else(|e| panic!("pos {i}: {e}"));
        }
    }

    #[test]
    fn decode_matches_seq_forward_neox() {
        let m = tiny_model(Arch::GeluNeoX);
        let tokens: Vec<u32> = vec![3, 8, 61, 0, 44];
        let seq_logits = forward_seq(&m, &tokens, None);
        let mut cache = KvCache::new(&m.cfg);
        for (i, &t) in tokens.iter().enumerate() {
            let logits = decode_step(&m, t, &mut cache).unwrap();
            crate::util::prop::close_slices(&logits, seq_logits.row(i), 2e-4, 2e-4)
                .unwrap_or_else(|e| panic!("pos {i}: {e}"));
        }
    }

    /// Decode the same token streams sequentially and batched (lockstep,
    /// equal lengths) and compare per-step logits.
    fn assert_batched_matches_sequential(m: &Model, streams: &[Vec<u32>]) {
        let n = streams.len();
        let len = streams[0].len();
        assert!(streams.iter().all(|s| s.len() == len));
        // Sequential oracle.
        let mut seq_caches: Vec<KvCache> = (0..n).map(|_| KvCache::new(&m.cfg)).collect();
        let mut seq_logits: Vec<Vec<Vec<f32>>> = vec![Vec::new(); n];
        for t in 0..len {
            for (i, s) in streams.iter().enumerate() {
                seq_logits[i].push(decode_step(m, s[t], &mut seq_caches[i]).unwrap());
            }
        }
        // Batched.
        let mut caches: Vec<KvCache> = (0..n).map(|_| KvCache::new(&m.cfg)).collect();
        for t in 0..len {
            let tokens: Vec<u32> = streams.iter().map(|s| s[t]).collect();
            let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
            let logits = decode_step_batch(m, &tokens, &mut refs).unwrap();
            for i in 0..n {
                crate::util::prop::close_slices(logits.row(i), &seq_logits[i][t], 1e-4, 1e-4)
                    .unwrap_or_else(|e| panic!("seq {i} step {t}: {e}"));
            }
        }
    }

    #[test]
    fn batched_decode_matches_sequential_swiglu() {
        let m = tiny_model(Arch::SwiGlu);
        let streams: Vec<Vec<u32>> = vec![
            vec![1, 5, 9, 30, 2, 17],
            vec![8, 8, 1, 0, 63, 2],
            vec![40, 3, 3, 12, 9, 1],
        ];
        assert_batched_matches_sequential(&m, &streams);
    }

    #[test]
    fn batched_decode_matches_sequential_neox() {
        let m = tiny_model(Arch::GeluNeoX);
        let streams: Vec<Vec<u32>> = vec![vec![3, 8, 61, 0, 44], vec![9, 1, 2, 3, 4]];
        assert_batched_matches_sequential(&m, &streams);
    }

    #[test]
    fn batched_decode_ragged_positions_match_sequential() {
        // Sequences at different cache depths share one engine pass.
        let m = tiny_model(Arch::SwiGlu);
        let a: Vec<u32> = vec![1, 5, 9, 30, 2, 17, 11];
        let b_toks: Vec<u32> = vec![8, 8, 1, 0];
        // Oracle.
        let mut ca = KvCache::new(&m.cfg);
        let mut cb = KvCache::new(&m.cfg);
        let mut want_a = Vec::new();
        let mut want_b = Vec::new();
        for &t in &a {
            want_a.push(decode_step(&m, t, &mut ca).unwrap());
        }
        for &t in &b_toks {
            want_b.push(decode_step(&m, t, &mut cb).unwrap());
        }
        // Batched with b joining three steps late (ragged join).
        let mut ca2 = KvCache::new(&m.cfg);
        let mut cb2 = KvCache::new(&m.cfg);
        for t in 0..a.len() {
            if t < 3 || t >= 3 + b_toks.len() {
                let mut refs = vec![&mut ca2];
                let logits = decode_step_batch(&m, &[a[t]], &mut refs).unwrap();
                crate::util::prop::close_slices(logits.row(0), &want_a[t], 1e-4, 1e-4)
                    .unwrap_or_else(|e| panic!("a step {t}: {e}"));
            } else {
                let mut refs = vec![&mut ca2, &mut cb2];
                let logits = decode_step_batch(&m, &[a[t], b_toks[t - 3]], &mut refs).unwrap();
                crate::util::prop::close_slices(logits.row(0), &want_a[t], 1e-4, 1e-4)
                    .unwrap_or_else(|e| panic!("a step {t}: {e}"));
                crate::util::prop::close_slices(logits.row(1), &want_b[t - 3], 1e-4, 1e-4)
                    .unwrap_or_else(|e| panic!("b step {}: {e}", t - 3));
            }
        }
    }

    #[test]
    fn decode_batch_joins_retires_and_respects_capacity() {
        let m = tiny_model(Arch::SwiGlu);
        let mut batch = DecodeBatch::new(&m.cfg, 2);
        assert_eq!(batch.capacity(), 2);
        let id0 = batch.try_join(vec![1, 2, 3], 2).unwrap();
        let id1 = batch.try_join(vec![4, 5], 3).unwrap();
        assert!(batch.try_join(vec![6], 1).is_none(), "capacity 2 must refuse a third join");
        assert_eq!(batch.active(), 2);

        let mut finished = Vec::new();
        let mut guard = 0;
        while batch.has_work() {
            batch.step(&m);
            finished.extend(batch.retire_finished());
            guard += 1;
            assert!(guard < 64, "decode batch failed to converge");
        }
        finished.extend(batch.retire_finished());
        assert_eq!(finished.len(), 2);
        assert_eq!(batch.active(), 0);
        let f0 = finished.iter().find(|f| f.id == id0).unwrap();
        let f1 = finished.iter().find(|f| f.id == id1).unwrap();
        assert_eq!(f0.generated.len(), 2);
        assert_eq!(f1.generated.len(), 3);
        // Slots are reusable after retirement.
        assert!(batch.try_join(vec![7, 8], 1).is_some());
        assert!(batch.steps > 0 && batch.tokens_processed >= batch.steps);
    }

    #[test]
    fn decode_batch_matches_greedy_decode_token_stream() {
        // The single-sequence DecodeBatch must reproduce greedy_decode's
        // token-level schedule: feed prompt, then emit n greedy tokens.
        // The oracle walks the same batched engine pass manually, so the
        // comparison checks the *schedule* bit-for-bit (logits equivalence
        // to the sequential path is covered separately with tolerances).
        let m = tiny_model(Arch::GeluNeoX);
        let prompt: Vec<u32> = vec![3, 8, 61];
        let n_gen = 4;
        let mut cache = KvCache::new(&m.cfg);
        let mut logits: Vec<f32> = Vec::new();
        for &t in &prompt {
            let mut refs = vec![&mut cache];
            logits = decode_step_batch(&m, &[t], &mut refs).unwrap().row(0).to_vec();
        }
        let mut want = Vec::new();
        for g in 0..n_gen {
            let next = crate::eval::argmax(&logits) as u32;
            want.push(next);
            if g + 1 < n_gen {
                let mut refs = vec![&mut cache];
                logits = decode_step_batch(&m, &[next], &mut refs).unwrap().row(0).to_vec();
            }
        }
        // Batched (capacity 1).
        let mut batch = DecodeBatch::new(&m.cfg, 1);
        batch.try_join(prompt, n_gen).unwrap();
        while batch.has_work() {
            batch.step(&m);
        }
        let got = &batch.retire_finished()[0];
        assert_eq!(got.generated, want);
    }

    #[test]
    fn decode_batch_handles_degenerate_sequences() {
        let m = tiny_model(Arch::SwiGlu);
        let mut batch = DecodeBatch::new(&m.cfg, 3);
        batch.try_join(vec![], 4).unwrap(); // empty prompt: born finished
        batch.try_join(vec![1, 2], 0).unwrap(); // prefill-only
        // Prompt longer than max_seq: truncated prefill, no panic.
        let long: Vec<u32> = (0..m.cfg.max_seq as u32 + 8).map(|i| i % 60).collect();
        batch.try_join(long, 2).unwrap();
        let mut guard = 0;
        while batch.has_work() {
            batch.step(&m);
            batch.retire_finished();
            guard += 1;
            assert!(guard < 2 * m.cfg.max_seq + 16, "did not converge");
        }
        batch.retire_finished();
        assert_eq!(batch.active(), 0);
    }

    #[test]
    fn chunked_multi_pass_prefill_is_bitwise_identical_to_single_rows() {
        // Kernel-level pin for chunked prefill: feeding a prompt through
        // decode_step_batch_multi in chunks of C produces byte-identical
        // per-position logits AND byte-identical KV to feeding it one
        // token per pass — in-pass causality makes the chunk exact, not
        // approximately equal.
        let m = tiny_model(Arch::SwiGlu);
        let prompt: Vec<u32> = (0..20u32).map(|i| (i * 7 + 3) % 60).collect();
        // Oracle: one token per pass.
        let mut oracle_cache = KvCache::new(&m.cfg);
        let mut oracle_logits: Vec<Vec<f32>> = Vec::new();
        for &t in &prompt {
            let rows = [(0usize, t)];
            let mut refs = vec![&mut oracle_cache];
            let l = decode_step_batch_multi(&m, &rows, &mut refs, None).unwrap();
            oracle_logits.push(l.row(0).to_vec());
        }
        for chunk in [1usize, 4, 7, 16, 256] {
            let mut cache = KvCache::new(&m.cfg);
            let mut got: Vec<Vec<f32>> = Vec::new();
            let mut fed = 0;
            while fed < prompt.len() {
                let c = chunk.min(prompt.len() - fed);
                let rows: Vec<(usize, u32)> =
                    prompt[fed..fed + c].iter().map(|&t| (0usize, t)).collect();
                let mut refs = vec![&mut cache];
                let l = decode_step_batch_multi(&m, &rows, &mut refs, None).unwrap();
                for r in 0..c {
                    got.push(l.row(r).to_vec());
                }
                fed += c;
            }
            assert_eq!(got, oracle_logits, "chunk {chunk}: logits must be bitwise equal");
            assert_eq!(cache.len(), oracle_cache.len());
            for layer in 0..m.cfg.n_layers {
                let n = cache.len() * m.cfg.d_model;
                assert_eq!(
                    cache.k[layer].data[..n],
                    oracle_cache.k[layer].data[..n],
                    "chunk {chunk} layer {layer}: K cache must be bitwise equal"
                );
                assert_eq!(
                    cache.v[layer].data[..n],
                    oracle_cache.v[layer].data[..n],
                    "chunk {chunk} layer {layer}: V cache must be bitwise equal"
                );
            }
        }
    }

    #[test]
    fn decode_batch_chunked_prefill_matches_monolithic_with_spec_rows() {
        // End-to-end pin: a DecodeBatch running chunked prefill emits
        // byte-identical token streams to the chunk=1 baseline, including
        // when a speculative-decoding row shares the batch and when rows
        // at different prefill depths interleave. Chunk 256 ≥ every
        // prompt, so it also covers the "whole prompt in one pass" case.
        let m = tiny_model(Arch::GeluNeoX);
        let run = |chunk: usize| -> Vec<(u64, Vec<u32>)> {
            let mut batch = DecodeBatch::new(&m.cfg, 3);
            batch.set_prefill_chunk(chunk);
            batch.set_spec(crate::spec::SpecConfig { default_k: 0, draft_rate: 0.5 });
            let long: Vec<u32> = (0..20u32).map(|i| (i * 5 + 1) % 60).collect();
            batch.try_join(long, 6).unwrap();
            let mut spec = SeqSpec::greedy(vec![9, 1, 2, 3, 4], 8);
            spec.spec_k = Some(3); // speculative row sharing the batch
            batch.try_join_spec(spec).unwrap();
            batch.try_join(vec![40, 3, 3], 5).unwrap();
            let mut out = Vec::new();
            let mut guard = 0;
            while batch.has_work() {
                batch.step(&m);
                out.extend(
                    batch.retire_finished().into_iter().map(|f| (f.id, f.generated)),
                );
                guard += 1;
                assert!(guard < 128, "chunk {chunk}: did not converge");
            }
            out.extend(batch.retire_finished().into_iter().map(|f| (f.id, f.generated)));
            out.sort_by_key(|&(id, _)| id);
            out
        };
        let baseline = run(1);
        assert_eq!(baseline.len(), 3);
        assert!(baseline.iter().all(|(_, g)| !g.is_empty()));
        for chunk in [4usize, 16, 256] {
            assert_eq!(run(chunk), baseline, "chunk {chunk} diverged from chunk 1");
        }
    }

    #[test]
    fn chunked_prefill_reduces_passes_to_first_token() {
        // The mechanism behind the TTFT win: a length-L prefill takes
        // ⌈L/C⌉ passes instead of L.
        let m = tiny_model(Arch::SwiGlu);
        let prompt: Vec<u32> = (0..24u32).map(|i| i % 60).collect();
        let passes = |chunk: usize| -> u64 {
            let mut batch = DecodeBatch::new(&m.cfg, 1);
            batch.set_prefill_chunk(chunk);
            batch.try_join(prompt.clone(), 1).unwrap();
            while batch.drain_emitted().is_empty() && batch.has_work() {
                batch.step(&m);
            }
            batch.steps
        };
        // The first token is sampled from held logits during selection (no
        // extra engine pass), so passes-to-first-token = prefill passes.
        assert_eq!(passes(1), 24, "chunk 1: one engine pass per prompt token");
        assert_eq!(passes(8), 3, "chunk 8: ⌈24/8⌉ prefill passes");
        assert_eq!(passes(256), 1, "whole prompt in one pass");
    }

    #[test]
    fn capture_collects_expected_shapes() {
        let m = tiny_model(Arch::SwiGlu);
        let tokens: Vec<u32> = vec![1, 2, 3, 4];
        let mut cap = Capture::new(m.cfg.n_layers);
        let _ = forward_seq(&m, &tokens, Some(&mut cap));
        for l in 0..m.cfg.n_layers {
            assert_eq!(cap.qkv_in[l].len(), 4 * m.cfg.d_model);
            assert_eq!(cap.mlp_in[l].len(), 4 * m.cfg.d_model);
            assert_eq!(cap.down_in[l].len(), 4 * m.cfg.d_hidden);
        }
        let x = Capture::x_matrix(&cap.qkv_in[0], m.cfg.d_model);
        assert_eq!((x.rows, x.cols), (m.cfg.d_model, 4));
    }

    #[test]
    fn logits_depend_on_context() {
        let m = tiny_model(Arch::SwiGlu);
        let a = forward_seq(&m, &[1, 2, 3], None);
        let b = forward_seq(&m, &[9, 2, 3], None);
        // Same last token, different context → different last-row logits.
        let diff: f32 = a
            .row(2)
            .iter()
            .zip(b.row(2))
            .map(|(x, y)| (x - y).abs())
            .sum();
        assert!(diff > 1e-3);
    }

    #[test]
    fn prefix_property_of_causal_lm() {
        // Logits at position i must not depend on tokens after i.
        let m = tiny_model(Arch::GeluNeoX);
        let full = forward_seq(&m, &[5, 6, 7, 8], None);
        let prefix = forward_seq(&m, &[5, 6], None);
        crate::util::prop::close_slices(full.row(0), prefix.row(0), 1e-4, 1e-4).unwrap();
        crate::util::prop::close_slices(full.row(1), prefix.row(1), 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn all_preset_configs_forward() {
        for cfg in [ModelConfig::pythia_sim(PythiaSize::S)] {
            let w = ModelWeights::random_init(&cfg, 5);
            let m = Model::new(cfg, w).unwrap();
            let logits = forward_seq(&m, &[1, 2, 3], None);
            assert_eq!(logits.rows, 3);
            assert_eq!(logits.cols, m.cfg.vocab);
            assert!(logits.data.iter().all(|v| v.is_finite()));
        }
    }
}
