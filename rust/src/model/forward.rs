//! Generic forward pass over pluggable block operators.
//!
//! [`BlockOps`] abstracts the three places adapters intervene — QKV, the
//! attention output projection (never adapted, but kept symmetric) and the
//! MLP — over both execution paths:
//!
//! * the **sequence path** (`forward_seq`): GEMM-based, used for
//!   perplexity / task scoring / calibration capture;
//! * the **decode path** (`decode_step`): GEMV + KV-cache, used by the
//!   serving coordinator and latency benchmarks (where masked skipping
//!   yields real wall-clock wins).
//!
//! The dense model implements `BlockOps` here; RaNA/CATS/… adapted models
//! implement it in [`crate::adapters`], and every evaluation harness is
//! generic over it — the paper's technique is a first-class plug-in, not a
//! fork of the model code.

use super::config::{Arch, ModelConfig};
use super::ops;
use super::weights::ModelWeights;
use crate::tensor::Mat;

/// Calibration capture: hidden states observed at adapter insertion points.
/// Rows are samples; `to_x_matrix` transposes into the `X ∈ R^{i×k}` layout
/// of the paper's Eqn. 7.
#[derive(Default)]
pub struct Capture {
    /// Input to QKV (post-norm1), per layer: rows of dim `d_model`.
    pub qkv_in: Vec<Vec<f32>>,
    /// Input to Up/Gate (post-norm2), per layer: rows of dim `d_model`.
    pub mlp_in: Vec<Vec<f32>>,
    /// Input to Down (the MLP intermediate), per layer: rows of dim `d_hidden`.
    pub down_in: Vec<Vec<f32>>,
    pub n_layers: usize,
}

impl Capture {
    pub fn new(n_layers: usize) -> Self {
        Self {
            qkv_in: vec![Vec::new(); n_layers],
            mlp_in: vec![Vec::new(); n_layers],
            down_in: vec![Vec::new(); n_layers],
            n_layers,
        }
    }

    pub fn push(buf: &mut Vec<f32>, rows: &Mat) {
        buf.extend_from_slice(&rows.data);
    }

    /// Samples collected for layer `l` at a site, as `X: i×k` (columns are
    /// hidden states, the layout of Eqn. 7).
    pub fn x_matrix(buf: &[f32], dim: usize) -> Mat {
        let k = buf.len() / dim;
        Mat::from_vec(k, dim, buf.to_vec()).transpose()
    }
}

/// Pluggable per-layer computation.
pub trait BlockOps: Sync {
    fn config(&self) -> &ModelConfig;
    fn weights(&self) -> &ModelWeights;

    // --- sequence (GEMM) path -------------------------------------------
    fn qkv_seq(&self, layer: usize, xs: &Mat) -> (Mat, Mat, Mat);
    fn attn_out_seq(&self, layer: usize, xs: &Mat) -> Mat;
    fn mlp_seq(&self, layer: usize, xs: &Mat, cap: Option<&mut Capture>) -> Mat;

    // --- decode (GEMV) path ---------------------------------------------
    fn qkv_tok(&self, layer: usize, x: &[f32]) -> (Vec<f32>, Vec<f32>, Vec<f32>);
    fn attn_out_tok(&self, layer: usize, x: &[f32]) -> Vec<f32>;
    fn mlp_tok(&self, layer: usize, x: &[f32]) -> Vec<f32>;
}

/// The dense (unadapted) model.
pub struct Model {
    pub cfg: ModelConfig,
    pub w: ModelWeights,
}

impl Model {
    pub fn new(cfg: ModelConfig, w: ModelWeights) -> anyhow::Result<Self> {
        w.validate(&cfg)?;
        Ok(Self { cfg, w })
    }

    pub fn load(dir: &std::path::Path) -> anyhow::Result<Self> {
        let (cfg, w) = ModelWeights::load(dir)?;
        Ok(Self { cfg, w })
    }

    fn dense_mlp_seq(&self, layer: usize, xs: &Mat, cap: Option<&mut Capture>) -> Mat {
        let l = &self.w.layers[layer];
        let inter = match self.cfg.arch {
            Arch::SwiGlu => {
                let up = l.up.apply_seq(xs);
                let gate = l.gate.as_ref().unwrap().apply_seq(xs);
                let mut inter = up;
                for (v, g) in inter.data.iter_mut().zip(&gate.data) {
                    *v *= ops::silu(*g);
                }
                inter
            }
            Arch::GeluNeoX => {
                let mut up = l.up.apply_seq(xs);
                for v in up.data.iter_mut() {
                    *v = ops::gelu(*v);
                }
                up
            }
        };
        if let Some(cap) = cap {
            Capture::push(&mut cap.down_in[layer], &inter);
        }
        l.down.apply_seq(&inter)
    }

    fn dense_mlp_tok(&self, layer: usize, x: &[f32]) -> Vec<f32> {
        let l = &self.w.layers[layer];
        let inter: Vec<f32> = match self.cfg.arch {
            Arch::SwiGlu => {
                let up = l.up.apply(x);
                let gate = l.gate.as_ref().unwrap().apply(x);
                up.iter().zip(&gate).map(|(&u, &g)| u * ops::silu(g)).collect()
            }
            Arch::GeluNeoX => l.up.apply(x).iter().map(|&v| ops::gelu(v)).collect(),
        };
        l.down.apply(&inter)
    }
}

impl BlockOps for Model {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn weights(&self) -> &ModelWeights {
        &self.w
    }

    fn qkv_seq(&self, layer: usize, xs: &Mat) -> (Mat, Mat, Mat) {
        let l = &self.w.layers[layer];
        (l.wq.apply_seq(xs), l.wk.apply_seq(xs), l.wv.apply_seq(xs))
    }

    fn attn_out_seq(&self, layer: usize, xs: &Mat) -> Mat {
        self.w.layers[layer].wo.apply_seq(xs)
    }

    fn mlp_seq(&self, layer: usize, xs: &Mat, cap: Option<&mut Capture>) -> Mat {
        self.dense_mlp_seq(layer, xs, cap)
    }

    fn qkv_tok(&self, layer: usize, x: &[f32]) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let l = &self.w.layers[layer];
        (l.wq.apply(x), l.wk.apply(x), l.wv.apply(x))
    }

    fn attn_out_tok(&self, layer: usize, x: &[f32]) -> Vec<f32> {
        self.w.layers[layer].wo.apply(x)
    }

    fn mlp_tok(&self, layer: usize, x: &[f32]) -> Vec<f32> {
        self.dense_mlp_tok(layer, x)
    }
}

/// Apply the arch's norm to every row.
fn norm_rows(cfg: &ModelConfig, norm: &super::weights::Norm, xs: &Mat) -> Mat {
    let mut out = Mat::zeros(xs.rows, xs.cols);
    for r in 0..xs.rows {
        let y = match cfg.arch {
            Arch::SwiGlu => ops::rmsnorm(xs.row(r), &norm.scale, cfg.norm_eps),
            Arch::GeluNeoX => ops::layernorm(
                xs.row(r),
                &norm.scale,
                norm.bias.as_ref().expect("neox norm bias"),
                cfg.norm_eps,
            ),
        };
        out.row_mut(r).copy_from_slice(&y);
    }
    out
}

fn norm_tok(cfg: &ModelConfig, norm: &super::weights::Norm, x: &[f32]) -> Vec<f32> {
    match cfg.arch {
        Arch::SwiGlu => ops::rmsnorm(x, &norm.scale, cfg.norm_eps),
        Arch::GeluNeoX => ops::layernorm(
            x,
            &norm.scale,
            norm.bias.as_ref().expect("neox norm bias"),
            cfg.norm_eps,
        ),
    }
}

/// Full-sequence forward: returns logits `[T, vocab]`. `positions[i] = i`.
pub fn forward_seq<B: BlockOps>(b: &B, tokens: &[u32], mut cap: Option<&mut Capture>) -> Mat {
    let cfg = b.config().clone();
    let w = b.weights();
    let t = tokens.len();
    let mut xs = Mat::zeros(t, cfg.d_model);
    for (r, &tok) in tokens.iter().enumerate() {
        xs.row_mut(r).copy_from_slice(w.embed.row(tok as usize));
    }

    for layer in 0..cfg.n_layers {
        let lw = &w.layers[layer];
        let h1 = norm_rows(&cfg, &lw.norm1, &xs);
        if let Some(cap) = cap.as_deref_mut() {
            Capture::push(&mut cap.qkv_in[layer], &h1);
        }
        let (mut q, mut k, v) = b.qkv_seq(layer, &h1);
        for r in 0..t {
            ops::rope_heads(q.row_mut(r), cfg.n_heads, r, cfg.rope_theta);
            ops::rope_heads(k.row_mut(r), cfg.n_heads, r, cfg.rope_theta);
        }
        let attn = ops::causal_attention_seq(&q, &k, &v, cfg.n_heads);
        let attn_o = b.attn_out_seq(layer, &attn);

        match cfg.arch {
            Arch::SwiGlu => {
                // Sequential residual: x += attn; x += mlp(norm2(x)).
                for i in 0..xs.data.len() {
                    xs.data[i] += attn_o.data[i];
                }
                let h2 = norm_rows(&cfg, &lw.norm2, &xs);
                if let Some(cap) = cap.as_deref_mut() {
                    Capture::push(&mut cap.mlp_in[layer], &h2);
                }
                let m = b.mlp_seq(layer, &h2, cap.as_deref_mut());
                for i in 0..xs.data.len() {
                    xs.data[i] += m.data[i];
                }
            }
            Arch::GeluNeoX => {
                // Parallel residual: x += attn(norm1(x)) + mlp(norm2(x)).
                let h2 = norm_rows(&cfg, &lw.norm2, &xs);
                if let Some(cap) = cap.as_deref_mut() {
                    Capture::push(&mut cap.mlp_in[layer], &h2);
                }
                let m = b.mlp_seq(layer, &h2, cap.as_deref_mut());
                for i in 0..xs.data.len() {
                    xs.data[i] += attn_o.data[i] + m.data[i];
                }
            }
        }
    }

    let hf = norm_rows(&cfg, &w.final_norm, &xs);
    hf.matmul(&w.lm_head.wt)
}

/// KV cache for incremental decoding.
pub struct KvCache {
    k: Vec<Mat>,
    v: Vec<Mat>,
    len: usize,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig) -> Self {
        Self {
            k: (0..cfg.n_layers).map(|_| Mat::zeros(cfg.max_seq, cfg.d_model)).collect(),
            v: (0..cfg.n_layers).map(|_| Mat::zeros(cfg.max_seq, cfg.d_model)).collect(),
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn clear(&mut self) {
        self.len = 0;
    }
}

/// One decode step: append `token` at position `cache.len()`, return logits.
pub fn decode_step<B: BlockOps>(b: &B, token: u32, cache: &mut KvCache) -> Vec<f32> {
    let cfg = b.config().clone();
    let w = b.weights();
    let pos = cache.len;
    assert!(pos < cfg.max_seq, "KV cache full");
    let mut x: Vec<f32> = w.embed.row(token as usize).to_vec();

    for layer in 0..cfg.n_layers {
        let lw = &w.layers[layer];
        let h1 = norm_tok(&cfg, &lw.norm1, &x);
        let (mut q, mut k, v) = b.qkv_tok(layer, &h1);
        ops::rope_heads(&mut q, cfg.n_heads, pos, cfg.rope_theta);
        ops::rope_heads(&mut k, cfg.n_heads, pos, cfg.rope_theta);
        cache.k[layer].row_mut(pos).copy_from_slice(&k);
        cache.v[layer].row_mut(pos).copy_from_slice(&v);

        // Attend over rows 0..=pos of the cache.
        let attn = attention_over_cache(&q, &cache.k[layer], &cache.v[layer], pos + 1, cfg.n_heads);
        let attn_o = b.attn_out_tok(layer, &attn);

        match cfg.arch {
            Arch::SwiGlu => {
                for i in 0..x.len() {
                    x[i] += attn_o[i];
                }
                let h2 = norm_tok(&cfg, &lw.norm2, &x);
                let m = b.mlp_tok(layer, &h2);
                for i in 0..x.len() {
                    x[i] += m[i];
                }
            }
            Arch::GeluNeoX => {
                let h2 = norm_tok(&cfg, &lw.norm2, &x);
                let m = b.mlp_tok(layer, &h2);
                for i in 0..x.len() {
                    x[i] += attn_o[i] + m[i];
                }
            }
        }
    }
    cache.len = pos + 1;

    let hf = norm_tok(&cfg, &w.final_norm, &x);
    w.lm_head.apply(&hf)
}

/// Attention for the decode path against the first `ctx` cache rows.
fn attention_over_cache(q: &[f32], k: &Mat, v: &Mat, ctx: usize, n_heads: usize) -> Vec<f32> {
    let d = q.len();
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = vec![0.0f32; d];
    let mut scores = vec![0.0f32; ctx];
    for h in 0..n_heads {
        let off = h * hd;
        for (ki, s) in scores.iter_mut().enumerate() {
            *s = crate::tensor::dot(&q[off..off + hd], &k.row(ki)[off..off + hd]) * scale;
        }
        ops::softmax(&mut scores);
        for (ki, &sc) in scores.iter().enumerate() {
            crate::tensor::axpy(sc, &v.row(ki)[off..off + hd], &mut out[off..off + hd]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::PythiaSize;

    fn tiny_cfg(arch: Arch) -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            arch,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_hidden: 32,
            vocab: 64,
            max_seq: 32,
            rope_theta: 10_000.0,
            norm_eps: 1e-5,
        }
    }

    fn tiny_model(arch: Arch) -> Model {
        let cfg = tiny_cfg(arch);
        let w = ModelWeights::random_init(&cfg, 11);
        Model::new(cfg, w).unwrap()
    }

    #[test]
    fn decode_matches_seq_forward_swiglu() {
        let m = tiny_model(Arch::SwiGlu);
        let tokens: Vec<u32> = vec![1, 5, 9, 30, 2, 17];
        let seq_logits = forward_seq(&m, &tokens, None);
        let mut cache = KvCache::new(&m.cfg);
        for (i, &t) in tokens.iter().enumerate() {
            let logits = decode_step(&m, t, &mut cache);
            crate::util::prop::close_slices(&logits, seq_logits.row(i), 2e-4, 2e-4)
                .unwrap_or_else(|e| panic!("pos {i}: {e}"));
        }
    }

    #[test]
    fn decode_matches_seq_forward_neox() {
        let m = tiny_model(Arch::GeluNeoX);
        let tokens: Vec<u32> = vec![3, 8, 61, 0, 44];
        let seq_logits = forward_seq(&m, &tokens, None);
        let mut cache = KvCache::new(&m.cfg);
        for (i, &t) in tokens.iter().enumerate() {
            let logits = decode_step(&m, t, &mut cache);
            crate::util::prop::close_slices(&logits, seq_logits.row(i), 2e-4, 2e-4)
                .unwrap_or_else(|e| panic!("pos {i}: {e}"));
        }
    }

    #[test]
    fn capture_collects_expected_shapes() {
        let m = tiny_model(Arch::SwiGlu);
        let tokens: Vec<u32> = vec![1, 2, 3, 4];
        let mut cap = Capture::new(m.cfg.n_layers);
        let _ = forward_seq(&m, &tokens, Some(&mut cap));
        for l in 0..m.cfg.n_layers {
            assert_eq!(cap.qkv_in[l].len(), 4 * m.cfg.d_model);
            assert_eq!(cap.mlp_in[l].len(), 4 * m.cfg.d_model);
            assert_eq!(cap.down_in[l].len(), 4 * m.cfg.d_hidden);
        }
        let x = Capture::x_matrix(&cap.qkv_in[0], m.cfg.d_model);
        assert_eq!((x.rows, x.cols), (m.cfg.d_model, 4));
    }

    #[test]
    fn logits_depend_on_context() {
        let m = tiny_model(Arch::SwiGlu);
        let a = forward_seq(&m, &[1, 2, 3], None);
        let b = forward_seq(&m, &[9, 2, 3], None);
        // Same last token, different context → different last-row logits.
        let diff: f32 = a
            .row(2)
            .iter()
            .zip(b.row(2))
            .map(|(x, y)| (x - y).abs())
            .sum();
        assert!(diff > 1e-3);
    }

    #[test]
    fn prefix_property_of_causal_lm() {
        // Logits at position i must not depend on tokens after i.
        let m = tiny_model(Arch::GeluNeoX);
        let full = forward_seq(&m, &[5, 6, 7, 8], None);
        let prefix = forward_seq(&m, &[5, 6], None);
        crate::util::prop::close_slices(full.row(0), prefix.row(0), 1e-4, 1e-4).unwrap();
        crate::util::prop::close_slices(full.row(1), prefix.row(1), 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn all_preset_configs_forward() {
        for cfg in [ModelConfig::pythia_sim(PythiaSize::S)] {
            let w = ModelWeights::random_init(&cfg, 5);
            let m = Model::new(cfg, w).unwrap();
            let logits = forward_seq(&m, &[1, 2, 3], None);
            assert_eq!(logits.rows, 3);
            assert_eq!(logits.cols, m.cfg.vocab);
            assert!(logits.data.iter().all(|v| v.is_finite()));
        }
    }
}
