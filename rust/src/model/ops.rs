//! Elementwise / normalization / attention primitives shared by the dense
//! and adapted forward passes. Definitions mirror `python/compile/model.py`
//! exactly (tested against exported JAX goldens in `rust/tests/`).

use super::config::Arch;
use crate::flops::measured;
use crate::tensor::Mat;

/// RMSNorm: `x / sqrt(mean(x²) + eps) * scale`.
pub fn rmsnorm(x: &[f32], scale: &[f32], eps: f32) -> Vec<f32> {
    let ms: f64 =
        x.iter().map(|&v| (v as f64).powi(2)).sum::<f64>() / x.len() as f64;
    let inv = 1.0 / (ms + eps as f64).sqrt() as f32;
    x.iter().zip(scale).map(|(&v, &s)| v * inv * s).collect()
}

/// LayerNorm with scale and bias.
pub fn layernorm(x: &[f32], scale: &[f32], bias: &[f32], eps: f32) -> Vec<f32> {
    let n = x.len() as f64;
    let mean: f64 = x.iter().map(|&v| v as f64).sum::<f64>() / n;
    let var: f64 = x.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
    let inv = (1.0 / (var + eps as f64).sqrt()) as f32;
    let mean = mean as f32;
    x.iter()
        .zip(scale.iter().zip(bias))
        .map(|(&v, (&s, &b))| (v - mean) * inv * s + b)
        .collect()
}

/// SiLU (a.k.a. swish): `x * sigmoid(x)`.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// GeLU, tanh approximation (matches `jax.nn.gelu(approximate=True)`).
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608028654; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Apply the arch's MLP activation in place over a `[rows, d_hidden]`
/// intermediate: SwiGLU (`up ⊙ silu(gate)`, gate required) or GeLU over
/// `up` alone. Shared by the sequence and batched-decode MLP paths of the
/// dense model and the RaNA adapters.
pub fn mlp_activate(arch: Arch, up: &mut Mat, gate: Option<&Mat>) {
    // 2 FLOPs/element with a gate, 1 without — `MlpFlops::{dense_swiglu,
    // dense_gelu}.act` at batch width `rows`.
    match arch {
        Arch::SwiGlu => measured::add(2 * up.data.len() as u64, 12 * up.data.len() as u64),
        Arch::GeluNeoX => measured::add(up.data.len() as u64, 8 * up.data.len() as u64),
    }
    match arch {
        Arch::SwiGlu => {
            let gate = gate.expect("swiglu activation needs a gate");
            debug_assert_eq!(up.data.len(), gate.data.len());
            for (v, g) in up.data.iter_mut().zip(&gate.data) {
                *v *= silu(*g);
            }
        }
        Arch::GeluNeoX => {
            for v in up.data.iter_mut() {
                *v = gelu(*v);
            }
        }
    }
}

/// Numerically-stable in-place softmax. The implementation lives in
/// [`crate::tensor::attention`] so the contiguous and paged decode-path
/// attention kernels share it bit-for-bit with the sequence path.
pub use crate::tensor::attention::softmax;

/// Next-token sampling parameters for the decode path. The default
/// (`temperature = 0`) is exact greedy argmax, which keeps every
/// pre-existing decode-determinism pin intact; a positive temperature
/// enables seeded temperature / top-k / top-p (nucleus) sampling.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Sampling {
    /// 0 = greedy; softmax temperature otherwise.
    pub temperature: f64,
    /// Keep only the `top_k` highest logits before sampling (0 = all).
    pub top_k: usize,
    /// Nucleus mass: keep the smallest set of tokens whose probability
    /// exceeds `top_p` (1.0 = all).
    pub top_p: f64,
    /// Per-sequence RNG seed — plain decoding is a pure function of
    /// `(prompt, params, seed)`, independent of batch composition.
    /// Speculative decoding (`spec_k > 0`) keeps this bitwise guarantee at
    /// temperature 0 (greedy consumes no randomness); at a positive
    /// temperature its draft/accept schedule may consume the RNG
    /// differently under memory pressure, so sampled speculative output is
    /// **distribution**-identical rather than bitwise reproducible across
    /// batch compositions (DESIGN.md §2d).
    pub seed: u64,
}

impl Default for Sampling {
    fn default() -> Self {
        Self { temperature: 0.0, top_k: 0, top_p: 1.0, seed: 0 }
    }
}

impl Sampling {
    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0
    }
}

/// Sample the next token. Greedy when `s.temperature <= 0` (bit-identical
/// to `eval::argmax`); otherwise temperature-scaled softmax restricted by
/// top-k then top-p, drawn with the caller's per-sequence RNG.
///
/// NOTE: the non-greedy candidate pipeline here is intentionally mirrored
/// by [`sampling_dist`] (kept separate so this function's seeded draw
/// stream stays bitwise-pinned); any change to the filtering below must be
/// applied there too, or speculative rejection sampling stops drawing its
/// `q` from the distribution this sampler actually uses.
pub fn sample_token(logits: &[f32], s: &Sampling, rng: &mut crate::util::rng::Xoshiro256) -> u32 {
    debug_assert!(!logits.is_empty());
    if s.is_greedy() {
        return logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0) as u32;
    }
    // Candidates sorted by logit descending (ties by index for determinism).
    let mut cand: Vec<(usize, f32)> = logits.iter().copied().enumerate().collect();
    cand.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    if s.top_k > 0 {
        cand.truncate(s.top_k.max(1));
    }
    // Temperature-scaled softmax over the candidate set (stable: max-shift).
    let inv_t = 1.0 / s.temperature;
    let max = cand[0].1 as f64;
    let mut probs: Vec<f64> =
        cand.iter().map(|&(_, l)| ((l as f64 - max) * inv_t).exp()).collect();
    let z: f64 = probs.iter().sum();
    for p in probs.iter_mut() {
        *p /= z;
    }
    // Nucleus truncation: smallest prefix with mass > top_p.
    let mut n_keep = probs.len();
    if s.top_p < 1.0 {
        let mut acc = 0.0;
        for (i, &p) in probs.iter().enumerate() {
            acc += p;
            if acc > s.top_p {
                n_keep = i + 1;
                break;
            }
        }
    }
    let mass: f64 = probs[..n_keep].iter().sum();
    let mut u = rng.f64() * mass;
    for i in 0..n_keep {
        u -= probs[i];
        if u <= 0.0 {
            return cand[i].0 as u32;
        }
    }
    cand[n_keep - 1].0 as u32
}

/// The filtered next-token distribution `sample_token` draws from at
/// `s` (temperature scaling, then top-k, then top-p), as `(token, prob)`
/// pairs sorted by logit descending (ties by index), probabilities
/// normalized over the kept candidates. Used by speculative decoding's
/// rejection sampler, which needs explicit draft (`q`) and target (`p`)
/// probabilities rather than a single draw. Requires `temperature > 0`
/// (greedy has no distribution to reject against).
pub fn sampling_dist(logits: &[f32], s: &Sampling) -> Vec<(u32, f64)> {
    debug_assert!(!s.is_greedy(), "sampling_dist needs a positive temperature");
    let mut cand: Vec<(usize, f32)> = logits.iter().copied().enumerate().collect();
    cand.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    if s.top_k > 0 {
        cand.truncate(s.top_k.max(1));
    }
    let inv_t = 1.0 / s.temperature;
    let max = cand[0].1 as f64;
    let mut probs: Vec<f64> =
        cand.iter().map(|&(_, l)| ((l as f64 - max) * inv_t).exp()).collect();
    let z: f64 = probs.iter().sum();
    for p in probs.iter_mut() {
        *p /= z;
    }
    let mut n_keep = probs.len();
    if s.top_p < 1.0 {
        let mut acc = 0.0;
        for (i, &p) in probs.iter().enumerate() {
            acc += p;
            if acc > s.top_p {
                n_keep = i + 1;
                break;
            }
        }
    }
    let mass: f64 = probs[..n_keep].iter().sum();
    cand[..n_keep]
        .iter()
        .zip(&probs)
        .map(|(&(tok, _), &p)| (tok as u32, p / mass))
        .collect()
}

/// Draw from an explicit `(token, prob)` distribution (probabilities need
/// not be normalized; the draw scales by their sum). Deterministic given
/// the RNG state and the pair order.
pub fn sample_from_dist(dist: &[(u32, f64)], rng: &mut crate::util::rng::Xoshiro256) -> u32 {
    debug_assert!(!dist.is_empty());
    let mass: f64 = dist.iter().map(|&(_, p)| p).sum();
    let mut u = rng.f64() * mass;
    for &(tok, p) in dist {
        u -= p;
        if u <= 0.0 {
            return tok;
        }
    }
    dist[dist.len() - 1].0
}

/// Log-softmax value at one index (used for LM scoring without
/// materializing the whole normalized distribution).
pub fn log_softmax_at(logits: &[f32], idx: usize) -> f64 {
    let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lse: f64 =
        logits.iter().map(|&v| ((v as f64) - max).exp()).sum::<f64>().ln() + max;
    logits[idx] as f64 - lse
}

/// Rotary position embedding applied in-place to one head vector `v`
/// (length = head_dim, paired as (0, hd/2), (1, hd/2+1)… like jax's
/// split-half convention).
pub fn rope_in_place(v: &mut [f32], pos: usize, theta: f32) {
    let hd = v.len();
    let half = hd / 2;
    for i in 0..half {
        let freq = 1.0 / theta.powf(2.0 * i as f32 / hd as f32);
        let angle = pos as f32 * freq;
        let (sin, cos) = angle.sin_cos();
        let a = v[i];
        let b = v[i + half];
        v[i] = a * cos - b * sin;
        v[i + half] = a * sin + b * cos;
    }
}

/// Apply RoPE to every head of a packed `[n_heads * head_dim]` vector.
pub fn rope_heads(v: &mut [f32], n_heads: usize, pos: usize, theta: f32) {
    // 2·d per call; q and k each take one call per token, matching
    // `AttnFlops::dense`'s rope = 4·d.
    measured::add(2 * v.len() as u64, 8 * v.len() as u64);
    let hd = v.len() / n_heads;
    for h in 0..n_heads {
        rope_in_place(&mut v[h * hd..(h + 1) * hd], pos, theta);
    }
}

/// Causal multi-head attention over full sequences (gemm path).
/// `q`, `k`, `v` are `[T, d_model]`; returns `[T, d_model]`.
pub fn causal_attention_seq(q: &Mat, k: &Mat, v: &Mat, n_heads: usize) -> Mat {
    let t = q.rows;
    let d = q.cols;
    // Σ_{qi} 4·d·(qi+1) = 2·d·t·(t+1): the sequence-path sum of the
    // per-token attention cost model.
    measured::add(2 * (d * t * (t + 1)) as u64, 4 * (3 * t * d) as u64);
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = Mat::zeros(t, d);
    for h in 0..n_heads {
        let off = h * hd;
        for qi in 0..t {
            // scores over keys 0..=qi
            let mut scores: Vec<f32> = (0..=qi)
                .map(|ki| {
                    crate::tensor::dot(
                        &q.row(qi)[off..off + hd],
                        &k.row(ki)[off..off + hd],
                    ) * scale
                })
                .collect();
            softmax(&mut scores);
            let orow = out.row_mut(qi);
            for (ki, &w) in scores.iter().enumerate() {
                crate::tensor::axpy(w, &v.row(ki)[off..off + hd], &mut orow[off..off + hd]);
            }
        }
    }
    out
}

/// One decode step of causal attention against cached K/V (`[ctx, d]`).
pub fn causal_attention_step(
    q: &[f32],
    k_cache: &Mat,
    v_cache: &Mat,
    n_heads: usize,
) -> Vec<f32> {
    let ctx = k_cache.rows;
    let d = q.len();
    // Same per-token cost model as `tensor::attention_over_cache`.
    measured::add(4 * (d * ctx) as u64, 4 * (2 * d * ctx + 2 * d) as u64);
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = vec![0.0f32; d];
    for h in 0..n_heads {
        let off = h * hd;
        let mut scores: Vec<f32> = (0..ctx)
            .map(|ki| {
                crate::tensor::dot(&q[off..off + hd], &k_cache.row(ki)[off..off + hd]) * scale
            })
            .collect();
        softmax(&mut scores);
        for (ki, &w) in scores.iter().enumerate() {
            crate::tensor::axpy(w, &v_cache.row(ki)[off..off + hd], &mut out[off..off + hd]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn rmsnorm_unit_output_scale() {
        let x = vec![3.0f32, -4.0];
        let scale = vec![1.0f32, 1.0];
        let y = rmsnorm(&x, &scale, 0.0);
        // rms = sqrt((9+16)/2) = sqrt(12.5)
        let rms = 12.5f32.sqrt();
        assert!((y[0] - 3.0 / rms).abs() < 1e-6);
        assert!((y[1] + 4.0 / rms).abs() < 1e-6);
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let x = vec![1.0f32, 2.0, 3.0, 4.0];
        let s = vec![1.0f32; 4];
        let b = vec![0.0f32; 4];
        let y = layernorm(&x, &s, &b, 0.0);
        let mean: f32 = y.iter().sum::<f32>() / 4.0;
        let var: f32 = y.iter().map(|&v| (v - mean).powi(2)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-4);
    }

    #[test]
    fn activation_values() {
        assert!((silu(0.0)).abs() < 1e-7);
        assert!((silu(1.0) - 0.7310586).abs() < 1e-5);
        assert!(gelu(0.0).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
    }

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let mut x = vec![1000.0f32, 1001.0, 999.0];
        softmax(&mut x);
        let s: f32 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(x[1] > x[0] && x[0] > x[2]);
    }

    #[test]
    fn log_softmax_at_matches_direct() {
        let logits = vec![0.5f32, -1.0, 2.0];
        let mut probs = logits.clone();
        softmax(&mut probs);
        for i in 0..3 {
            assert!((log_softmax_at(&logits, i) - (probs[i] as f64).ln()).abs() < 1e-5);
        }
    }

    #[test]
    fn rope_preserves_norm_and_pos0_is_identity() {
        let mut v = vec![1.0f32, 2.0, 3.0, 4.0];
        let orig = v.clone();
        rope_in_place(&mut v, 0, 10_000.0);
        assert_eq!(v, orig);
        rope_in_place(&mut v, 7, 10_000.0);
        let n0: f32 = orig.iter().map(|x| x * x).sum();
        let n1: f32 = v.iter().map(|x| x * x).sum();
        assert!((n0 - n1).abs() < 1e-4);
        assert_ne!(v, orig);
    }

    #[test]
    fn rope_relative_property() {
        // <rope(q,m), rope(k,n)> depends only on m-n (per 2D pair).
        let q = vec![0.3f32, -0.7];
        let k = vec![1.1f32, 0.4];
        let dots: Vec<f32> = (0..3)
            .map(|shift| {
                let mut qq = q.clone();
                let mut kk = k.clone();
                rope_in_place(&mut qq, 5 + shift, 10_000.0);
                rope_in_place(&mut kk, 2 + shift, 10_000.0);
                crate::tensor::dot(&qq, &kk)
            })
            .collect();
        assert!((dots[0] - dots[1]).abs() < 1e-4);
        assert!((dots[1] - dots[2]).abs() < 1e-4);
    }

    #[test]
    fn attention_step_matches_seq_last_row() {
        let mut rng = Xoshiro256::new(3);
        let (t, d, heads) = (5, 8, 2);
        let q = Mat::gaussian(t, d, 1.0, &mut rng);
        let k = Mat::gaussian(t, d, 1.0, &mut rng);
        let v = Mat::gaussian(t, d, 1.0, &mut rng);
        let seq = causal_attention_seq(&q, &k, &v, heads);
        let step = causal_attention_step(q.row(t - 1), &k, &v, heads);
        crate::util::prop::close_slices(seq.row(t - 1), &step, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn sampler_greedy_matches_argmax_and_is_rng_free() {
        let logits: Vec<f32> = vec![0.1, 2.5, -1.0, 2.5, 0.0];
        let mut rng = Xoshiro256::new(1);
        let s = Sampling::default();
        assert!(s.is_greedy());
        // Greedy must not consume randomness and must pick the argmax
        // (first of tied maxima, like eval::argmax's max_by semantics).
        let before = rng.next_u64();
        let mut rng = Xoshiro256::new(1);
        let tok = sample_token(&logits, &s, &mut rng);
        assert_eq!(tok, crate::eval::argmax(&logits) as u32);
        assert_eq!(rng.next_u64(), before, "greedy sampling consumed rng state");
    }

    #[test]
    fn sampler_is_deterministic_and_respects_top_k() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32) * 0.3).collect();
        let s = Sampling { temperature: 0.8, top_k: 3, top_p: 1.0, seed: 7 };
        let mut r1 = Xoshiro256::new(s.seed);
        let mut r2 = Xoshiro256::new(s.seed);
        let draws1: Vec<u32> = (0..32).map(|_| sample_token(&logits, &s, &mut r1)).collect();
        let draws2: Vec<u32> = (0..32).map(|_| sample_token(&logits, &s, &mut r2)).collect();
        assert_eq!(draws1, draws2, "same seed must reproduce the stream");
        assert!(draws1.iter().all(|&t| t >= 13), "top-3 of ascending logits is {{13,14,15}}");
        assert!(draws1.iter().any(|&t| t != draws1[0]), "temperature must actually mix");
    }

    #[test]
    fn sampling_dist_matches_sampler_support_and_normalizes() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32) * 0.3).collect();
        let s = Sampling { temperature: 0.8, top_k: 3, top_p: 1.0, seed: 7 };
        let dist = sampling_dist(&logits, &s);
        assert_eq!(dist.len(), 3);
        assert!(dist.iter().all(|&(t, _)| t >= 13), "top-3 of ascending logits is {{13,14,15}}");
        let mass: f64 = dist.iter().map(|&(_, p)| p).sum();
        assert!((mass - 1.0).abs() < 1e-12);
        // Probabilities are logit-ordered descending.
        assert!(dist.windows(2).all(|w| w[0].1 >= w[1].1));
        // Every token the sampler can draw lies in the dist's support.
        let mut rng = Xoshiro256::new(s.seed);
        for _ in 0..64 {
            let tok = sample_token(&logits, &s, &mut rng);
            assert!(dist.iter().any(|&(t, _)| t == tok));
        }
        // A tight nucleus collapses the support like the sampler does.
        let mut peaked = vec![0.0f32; 8];
        peaked[5] = 10.0;
        let s = Sampling { temperature: 1.0, top_k: 0, top_p: 0.5, seed: 3 };
        let dist = sampling_dist(&peaked, &s);
        assert_eq!(dist.len(), 1);
        assert_eq!(dist[0].0, 5);
    }

    #[test]
    fn sample_from_dist_is_deterministic_and_weighted() {
        let dist = vec![(4u32, 0.7), (9u32, 0.3)];
        let mut r1 = Xoshiro256::new(11);
        let mut r2 = Xoshiro256::new(11);
        let a: Vec<u32> = (0..64).map(|_| sample_from_dist(&dist, &mut r1)).collect();
        let b: Vec<u32> = (0..64).map(|_| sample_from_dist(&dist, &mut r2)).collect();
        assert_eq!(a, b);
        let n4 = a.iter().filter(|&&t| t == 4).count();
        assert!(n4 > 32, "0.7-mass token drawn only {n4}/64 times");
        // Unnormalized weights scale the draw, not the outcome set.
        let scaled: Vec<(u32, f64)> = dist.iter().map(|&(t, p)| (t, p * 8.0)).collect();
        let mut r3 = Xoshiro256::new(11);
        let c: Vec<u32> = (0..64).map(|_| sample_from_dist(&scaled, &mut r3)).collect();
        assert_eq!(a, c);
    }

    #[test]
    fn sampler_top_p_prunes_the_tail() {
        // One dominant token: a tight nucleus keeps only it.
        let mut logits = vec![0.0f32; 8];
        logits[5] = 10.0;
        let s = Sampling { temperature: 1.0, top_k: 0, top_p: 0.5, seed: 3 };
        let mut rng = Xoshiro256::new(s.seed);
        for _ in 0..16 {
            assert_eq!(sample_token(&logits, &s, &mut rng), 5);
        }
    }

    #[test]
    fn attention_is_causal() {
        // Changing a future key/value must not change earlier outputs.
        let mut rng = Xoshiro256::new(4);
        let (t, d, heads) = (6, 4, 1);
        let q = Mat::gaussian(t, d, 1.0, &mut rng);
        let k = Mat::gaussian(t, d, 1.0, &mut rng);
        let v = Mat::gaussian(t, d, 1.0, &mut rng);
        let base = causal_attention_seq(&q, &k, &v, heads);
        let mut k2 = k.clone();
        let mut v2 = v.clone();
        for c in 0..d {
            *k2.at_mut(t - 1, c) += 5.0;
            *v2.at_mut(t - 1, c) -= 3.0;
        }
        let mod_out = causal_attention_seq(&q, &k2, &v2, heads);
        for r in 0..t - 1 {
            crate::util::prop::close_slices(base.row(r), mod_out.row(r), 1e-6, 1e-6).unwrap();
        }
    }
}
