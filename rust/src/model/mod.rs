//! Transformer reference implementation: configs, weights, and the generic
//! forward pass ([`BlockOps`]) that both the dense model and every adapted
//! model implement.

pub mod config;
pub mod forward;
pub mod ops;
pub mod paged;
pub mod weights;

pub use config::{Arch, ModelConfig, PythiaSize};
pub use forward::{
    decode_step, decode_step_batch, decode_step_batch_budgeted, decode_step_batch_multi,
    forward_seq, BlockOps, Capture, DecodeBatch, FinishedSeq, KvCache, Model, SeqSpec,
    AMBIENT_BUDGET,
};
pub use ops::Sampling;
pub use paged::{
    decode_step_paged, decode_step_paged_budgeted, decode_step_paged_multi, PagedBatchConfig,
    PagedDecodeBatch,
};
pub use weights::{LayerWeights, Linear, ModelWeights, Norm};

use std::path::PathBuf;

/// Directory holding a trained model's artifacts.
pub fn model_dir(name: &str) -> PathBuf {
    crate::util::artifacts_dir().join(name)
}

/// Load a trained model from `artifacts/<name>/`; falls back to a seeded
/// random init when artifacts have not been built (tests, smoke paths) —
/// callers that need trained weights should use [`Model::load`] directly.
pub fn load_or_random(name: &str, seed: u64) -> anyhow::Result<Model> {
    let dir = model_dir(name);
    if dir.join("manifest.json").exists() {
        Model::load(&dir)
    } else {
        let cfg = ModelConfig::by_name(name)?;
        let w = ModelWeights::random_init(&cfg, seed);
        Model::new(cfg, w)
    }
}
