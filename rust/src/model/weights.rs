//! Weight storage and the manifest/.bin interchange format.
//!
//! `python/compile/train.py` writes `artifacts/<model>/manifest.json` (the
//! config plus a tensor directory) and `weights.bin` (concatenated
//! little-endian f32). This module loads them into [`ModelWeights`]; for
//! tests that must run before `make artifacts`, [`ModelWeights::random_init`]
//! produces a weight set with realistic scales.
//!
//! Every linear is stored both as `w` (`out×in`, for GEMV decode) and as
//! `wt` (`in×out`, for the GEMM sequence path) — the transposes are built
//! once at load time.

use std::collections::BTreeMap;
use std::path::Path;

use super::config::{Arch, ModelConfig};
use crate::tensor::Mat;
use crate::util::json::Json;
use crate::util::rng::Xoshiro256;

/// A linear layer kept in both orientations.
#[derive(Clone, Debug)]
pub struct Linear {
    /// `out × in` — `y = w·x` (decode path).
    pub w: Mat,
    /// `in × out` — `ys = xs·wt` (sequence path).
    pub wt: Mat,
}

impl Linear {
    pub fn new(w: Mat) -> Self {
        let wt = w.transpose();
        Self { w, wt }
    }

    pub fn out_dim(&self) -> usize {
        self.w.rows
    }

    pub fn in_dim(&self) -> usize {
        self.w.cols
    }

    pub fn apply(&self, x: &[f32]) -> Vec<f32> {
        self.w.matvec(x)
    }

    /// Sequence path: `ys = xs @ wt` through the packed GEMM (single-row
    /// sequences dispatch to its GEMV fast path).
    pub fn apply_seq(&self, xs: &Mat) -> Mat {
        xs.matmul(&self.wt)
    }

    /// Batched decode path: one row per in-flight sequence, through the
    /// shared-stream batched GEMV — the weight matrix is streamed once per
    /// engine step instead of once per sequence, and each output row is
    /// bit-identical to the single-row GEMV path regardless of batch
    /// composition (the decode-determinism contract).
    pub fn apply_tok_batch(&self, xs: &Mat) -> Mat {
        assert_eq!(xs.cols, self.in_dim(), "apply_tok_batch shape mismatch");
        let mut out = Mat::zeros(xs.rows, self.out_dim());
        crate::tensor::gemm::gemv_batch(
            xs.rows,
            xs.cols,
            self.wt.cols,
            &xs.data,
            &self.wt.data,
            &mut out.data,
            1.0,
            0.0,
        );
        out
    }
}

/// Norm parameters (bias present only for LayerNorm archs).
#[derive(Clone, Debug)]
pub struct Norm {
    pub scale: Vec<f32>,
    pub bias: Option<Vec<f32>>,
}

/// Per-layer weights.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub wq: Linear,
    pub wk: Linear,
    pub wv: Linear,
    pub wo: Linear,
    pub up: Linear,
    /// Present for SwiGLU archs only.
    pub gate: Option<Linear>,
    pub down: Linear,
    pub norm1: Norm,
    pub norm2: Norm,
}

/// Full model weights.
pub struct ModelWeights {
    pub embed: Mat, // vocab × d
    pub layers: Vec<LayerWeights>,
    pub final_norm: Norm,
    pub lm_head: Linear, // vocab × d
}

impl ModelWeights {
    /// Scaled-gaussian initialization (same scheme as train.py's init) —
    /// used by tests and by the training-free smoke paths.
    pub fn random_init(cfg: &ModelConfig, seed: u64) -> Self {
        let mut rng = Xoshiro256::new(seed);
        let d = cfg.d_model;
        let h = cfg.d_hidden;
        let std_d = 1.0 / (d as f32).sqrt();
        let std_h = 1.0 / (h as f32).sqrt();
        let lin = |o: usize, i: usize, std: f32, rng: &mut Xoshiro256| {
            Linear::new(Mat::gaussian(o, i, std, rng))
        };
        let norm = |cfg: &ModelConfig, d: usize| Norm {
            scale: vec![1.0; d],
            bias: if cfg.arch == Arch::GeluNeoX { Some(vec![0.0; d]) } else { None },
        };
        let layers = (0..cfg.n_layers)
            .map(|_| LayerWeights {
                wq: lin(d, d, std_d, &mut rng),
                wk: lin(d, d, std_d, &mut rng),
                wv: lin(d, d, std_d, &mut rng),
                wo: lin(d, d, std_d, &mut rng),
                up: lin(h, d, std_d, &mut rng),
                gate: if cfg.arch == Arch::SwiGlu {
                    Some(lin(h, d, std_d, &mut rng))
                } else {
                    None
                },
                down: lin(d, h, std_h, &mut rng),
                norm1: norm(cfg, d),
                norm2: norm(cfg, d),
            })
            .collect();
        Self {
            embed: Mat::gaussian(cfg.vocab, d, 0.02, &mut rng),
            layers,
            final_norm: norm(cfg, d),
            lm_head: lin(cfg.vocab, d, std_d, &mut rng),
        }
    }

    /// Load a trained model from `dir/manifest.json` + `dir/weights.bin`.
    pub fn load(dir: &Path) -> anyhow::Result<(ModelConfig, ModelWeights)> {
        let manifest = Json::parse(&std::fs::read_to_string(dir.join("manifest.json"))?)?;
        let cfg = ModelConfig::from_json(manifest.get("config")?)?;
        let blob = crate::util::read_f32_bin(&dir.join("weights.bin"))?;

        // Tensor directory: name → (shape, offset in floats).
        let mut dirmap: BTreeMap<String, (Vec<usize>, usize)> = BTreeMap::new();
        for t in manifest
            .get("tensors")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("tensors not an array"))?
        {
            let name = t.get_str("name")?.to_string();
            let shape: Vec<usize> = t
                .get("shape")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("shape not an array"))?
                .iter()
                .map(|x| x.as_usize().unwrap_or(0))
                .collect();
            let offset = t.get_usize("offset")?;
            dirmap.insert(name, (shape, offset));
        }

        let fetch_mat = |name: &str| -> anyhow::Result<Mat> {
            let (shape, off) = dirmap
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("tensor {name:?} missing from manifest"))?;
            anyhow::ensure!(shape.len() == 2, "{name}: expected 2-d tensor");
            let n = shape[0] * shape[1];
            anyhow::ensure!(off + n <= blob.len(), "{name}: out of range");
            Ok(Mat::from_vec(shape[0], shape[1], blob[*off..off + n].to_vec()))
        };
        let fetch_vec = |name: &str| -> anyhow::Result<Vec<f32>> {
            let (shape, off) = dirmap
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("tensor {name:?} missing from manifest"))?;
            let n: usize = shape.iter().product();
            Ok(blob[*off..off + n].to_vec())
        };
        let fetch_norm = |prefix: &str, has_bias: bool| -> anyhow::Result<Norm> {
            Ok(Norm {
                scale: fetch_vec(&format!("{prefix}.scale"))?,
                bias: if has_bias {
                    Some(fetch_vec(&format!("{prefix}.bias"))?)
                } else {
                    None
                },
            })
        };

        let has_bias = cfg.arch == Arch::GeluNeoX;
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let p = format!("layers.{l}");
            layers.push(LayerWeights {
                wq: Linear::new(fetch_mat(&format!("{p}.attn.wq"))?),
                wk: Linear::new(fetch_mat(&format!("{p}.attn.wk"))?),
                wv: Linear::new(fetch_mat(&format!("{p}.attn.wv"))?),
                wo: Linear::new(fetch_mat(&format!("{p}.attn.wo"))?),
                up: Linear::new(fetch_mat(&format!("{p}.mlp.up"))?),
                gate: if cfg.arch == Arch::SwiGlu {
                    Some(Linear::new(fetch_mat(&format!("{p}.mlp.gate"))?))
                } else {
                    None
                },
                down: Linear::new(fetch_mat(&format!("{p}.mlp.down"))?),
                norm1: fetch_norm(&format!("{p}.norm1"), has_bias)?,
                norm2: fetch_norm(&format!("{p}.norm2"), has_bias)?,
            });
        }
        let weights = ModelWeights {
            embed: fetch_mat("embed")?,
            layers,
            final_norm: fetch_norm("final_norm", has_bias)?,
            lm_head: Linear::new(fetch_mat("lm_head")?),
        };
        weights.validate(&cfg)?;
        Ok((cfg, weights))
    }

    /// Shape-check against a config.
    pub fn validate(&self, cfg: &ModelConfig) -> anyhow::Result<()> {
        let (d, h, v) = (cfg.d_model, cfg.d_hidden, cfg.vocab);
        anyhow::ensure!(self.embed.rows == v && self.embed.cols == d, "embed shape");
        anyhow::ensure!(self.layers.len() == cfg.n_layers, "layer count");
        for (i, l) in self.layers.iter().enumerate() {
            let shapes = [
                (l.wq.w.rows, l.wq.w.cols, d, d, "wq"),
                (l.wk.w.rows, l.wk.w.cols, d, d, "wk"),
                (l.wv.w.rows, l.wv.w.cols, d, d, "wv"),
                (l.wo.w.rows, l.wo.w.cols, d, d, "wo"),
                (l.up.w.rows, l.up.w.cols, h, d, "up"),
                (l.down.w.rows, l.down.w.cols, d, h, "down"),
            ];
            for (r, c, er, ec, name) in shapes {
                anyhow::ensure!(r == er && c == ec, "layer {i} {name}: {r}×{c} != {er}×{ec}");
            }
            anyhow::ensure!(
                l.gate.is_some() == (cfg.arch == Arch::SwiGlu),
                "layer {i}: gate presence vs arch"
            );
            anyhow::ensure!(l.norm1.scale.len() == d, "layer {i} norm1");
            anyhow::ensure!(
                l.norm1.bias.is_some() == (cfg.arch == Arch::GeluNeoX),
                "layer {i}: norm bias vs arch"
            );
        }
        anyhow::ensure!(
            self.lm_head.w.rows == v && self.lm_head.w.cols == d,
            "lm_head shape"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::PythiaSize;

    #[test]
    fn random_init_validates_for_all_archs() {
        for cfg in ModelConfig::all() {
            let w = ModelWeights::random_init(&cfg, 1);
            w.validate(&cfg).unwrap();
        }
    }

    #[test]
    fn linear_orientations_agree() {
        let mut rng = Xoshiro256::new(2);
        let lin = Linear::new(Mat::gaussian(6, 4, 1.0, &mut rng));
        let x: Vec<f32> = (0..4).map(|_| rng.gaussian()).collect();
        let y1 = lin.apply(&x);
        let xs = Mat::from_vec(1, 4, x);
        let y2 = lin.apply_seq(&xs);
        crate::util::prop::close_slices(&y1, &y2.data, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn apply_seq_agrees_with_per_token_apply() {
        // tok/seq agreement through Linear::apply_seq across a shape large
        // enough to engage the packed GEMM path.
        let mut rng = Xoshiro256::new(5);
        let lin = Linear::new(Mat::gaussian(96, 80, 1.0, &mut rng));
        let xs = Mat::gaussian(64, 80, 1.0, &mut rng);
        let seq = lin.apply_seq(&xs);
        assert_eq!((seq.rows, seq.cols), (64, 96));
        for r in 0..xs.rows {
            let tok = lin.apply(xs.row(r));
            crate::util::prop::close_slices(&tok, seq.row(r), 1e-4, 1e-3)
                .unwrap_or_else(|e| panic!("row {r}: {e}"));
        }
    }

    #[test]
    fn apply_tok_batch_rows_match_single_row_path_bitwise() {
        // The batched decode path must be bit-identical to decoding each
        // row alone (batch-composition determinism).
        let mut rng = Xoshiro256::new(7);
        let lin = Linear::new(Mat::gaussian(96, 80, 1.0, &mut rng));
        let xs = Mat::gaussian(5, 80, 1.0, &mut rng);
        let batched = lin.apply_tok_batch(&xs);
        assert_eq!((batched.rows, batched.cols), (5, 96));
        for r in 0..xs.rows {
            let solo = lin.apply_tok_batch(&Mat::from_vec(1, 80, xs.row(r).to_vec()));
            assert_eq!(solo.data, batched.row(r).to_vec(), "row {r}");
            // And numerically consistent with the per-token GEMV decode path.
            crate::util::prop::close_slices(&solo.data, &lin.apply(xs.row(r)), 1e-4, 1e-3)
                .unwrap_or_else(|e| panic!("row {r}: {e}"));
        }
    }

    #[test]
    fn manifest_roundtrip_via_files() {
        // Write a tiny random model in the manifest format and load it back.
        let cfg = ModelConfig {
            name: "tiny".into(),
            d_model: 8,
            n_layers: 1,
            n_heads: 2,
            d_hidden: 16,
            vocab: 32,
            ..ModelConfig::pythia_sim(PythiaSize::S)
        };
        let w = ModelWeights::random_init(&cfg, 3);
        let dir = std::env::temp_dir().join(format!("rana-weights-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();

        // Serialize by walking the same naming scheme.
        let mut blob: Vec<f32> = Vec::new();
        let mut tensors: Vec<Json> = Vec::new();
        let mut push = |name: String, shape: Vec<usize>, data: &[f32], blob: &mut Vec<f32>| {
            tensors.push(Json::obj(vec![
                ("name", Json::Str(name)),
                ("shape", Json::arr_usize(&shape)),
                ("offset", Json::Num(blob.len() as f64)),
            ]));
            blob.extend_from_slice(data);
        };
        push("embed".into(), vec![cfg.vocab, cfg.d_model], &w.embed.data, &mut blob);
        let l = &w.layers[0];
        for (n, m) in [
            ("wq", &l.wq),
            ("wk", &l.wk),
            ("wv", &l.wv),
            ("wo", &l.wo),
        ] {
            push(format!("layers.0.attn.{n}"), vec![m.w.rows, m.w.cols], &m.w.data, &mut blob);
        }
        for (n, m) in [("up", &l.up), ("down", &l.down)] {
            push(format!("layers.0.mlp.{n}"), vec![m.w.rows, m.w.cols], &m.w.data, &mut blob);
        }
        for (n, norm) in [("norm1", &l.norm1), ("norm2", &l.norm2)] {
            push(format!("layers.0.{n}.scale"), vec![cfg.d_model], &norm.scale, &mut blob);
            push(
                format!("layers.0.{n}.bias"),
                vec![cfg.d_model],
                norm.bias.as_ref().unwrap(),
                &mut blob,
            );
        }
        push("final_norm.scale".into(), vec![cfg.d_model], &w.final_norm.scale, &mut blob);
        push(
            "final_norm.bias".into(),
            vec![cfg.d_model],
            w.final_norm.bias.as_ref().unwrap(),
            &mut blob,
        );
        push("lm_head".into(), vec![cfg.vocab, cfg.d_model], &w.lm_head.w.data, &mut blob);

        let manifest = Json::obj(vec![
            ("config", cfg.to_json()),
            ("tensors", Json::Arr(tensors)),
        ]);
        std::fs::write(dir.join("manifest.json"), manifest.to_string()).unwrap();
        crate::util::write_f32_bin(&dir.join("weights.bin"), &blob).unwrap();

        let (cfg2, w2) = ModelWeights::load(&dir).unwrap();
        assert_eq!(cfg2, cfg);
        assert_eq!(w2.embed, w.embed);
        assert_eq!(w2.layers[0].down.w, w.layers[0].down.w);
        assert_eq!(w2.lm_head.w, w.lm_head.w);
        std::fs::remove_dir_all(&dir).ok();
    }
}
