//! Model configurations.
//!
//! Three families simulate the paper's testbed (DESIGN.md §2):
//! * `llama-sim` — SwiGLU decoder, RMSNorm, RoPE (Llama2-7b stand-in);
//! * `gemma-sim` — SwiGLU decoder with a wider MLP (Gemma-2b stand-in;
//!   adapters applied to MLPs only, as in the paper §5.3);
//! * `pythia-sim-{s,m,l}` — GeLU NeoX-style decoders with parallel
//!   residual and LayerNorm (Pythia suite stand-in).

use crate::util::json::Json;

/// Architecture family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arch {
    /// Llama/Gemma style: RMSNorm, sequential residual, SwiGLU MLP.
    SwiGlu,
    /// GPT-NeoX style: LayerNorm, parallel residual, GeLU MLP.
    GeluNeoX,
}

impl Arch {
    pub fn as_str(&self) -> &'static str {
        match self {
            Arch::SwiGlu => "swiglu",
            Arch::GeluNeoX => "gelu_neox",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "swiglu" => Ok(Arch::SwiGlu),
            "gelu_neox" => Ok(Arch::GeluNeoX),
            other => anyhow::bail!("unknown arch {other:?}"),
        }
    }
}

/// Hyper-parameters of one model. Mirrored by `python/compile/model.py`;
/// the JSON manifest written at training time is the source of truth.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub arch: Arch,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_hidden: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub rope_theta: f32,
    pub norm_eps: f32,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Total parameter count (untied embeddings).
    pub fn n_params(&self) -> usize {
        let attn = 4 * self.d_model * self.d_model;
        let mlp = match self.arch {
            Arch::SwiGlu => 3 * self.d_model * self.d_hidden,
            Arch::GeluNeoX => 2 * self.d_model * self.d_hidden,
        };
        let norms = match self.arch {
            Arch::SwiGlu => 2 * self.d_model,
            Arch::GeluNeoX => 4 * self.d_model, // scale + bias, two norms
        };
        self.n_layers * (attn + mlp + norms)
            + 2 * self.vocab * self.d_model
            + self.d_model
    }

    /// Llama2-7b stand-in: SwiGLU, MLP ratio ≈ 2.67.
    pub fn llama_sim() -> Self {
        Self {
            name: "llama-sim".into(),
            arch: Arch::SwiGlu,
            d_model: 192,
            n_layers: 4,
            n_heads: 6,
            d_hidden: 512,
            vocab: crate::data::tokenizer::MODEL_VOCAB,
            max_seq: 512,
            rope_theta: 10_000.0,
            norm_eps: 1e-5,
        }
    }

    /// Gemma-2b stand-in: SwiGLU with wider MLP (ratio 4).
    pub fn gemma_sim() -> Self {
        Self {
            name: "gemma-sim".into(),
            arch: Arch::SwiGlu,
            d_model: 160,
            n_layers: 4,
            n_heads: 5,
            d_hidden: 640,
            vocab: crate::data::tokenizer::MODEL_VOCAB,
            max_seq: 512,
            rope_theta: 10_000.0,
            norm_eps: 1e-5,
        }
    }

    /// Pythia suite stand-ins (GeLU NeoX), three sizes.
    pub fn pythia_sim(size: PythiaSize) -> Self {
        let (name, d, l, h) = match size {
            PythiaSize::S => ("pythia-sim-s", 96, 4, 4),
            PythiaSize::M => ("pythia-sim-m", 144, 4, 4),
            PythiaSize::L => ("pythia-sim-l", 192, 5, 6),
        };
        Self {
            name: name.into(),
            arch: Arch::GeluNeoX,
            d_model: d,
            n_layers: l,
            n_heads: h,
            d_hidden: 4 * d,
            vocab: crate::data::tokenizer::MODEL_VOCAB,
            max_seq: 512,
            rope_theta: 10_000.0,
            norm_eps: 1e-5,
        }
    }

    /// All model configs in the canonical order used by `make artifacts`.
    pub fn all() -> Vec<ModelConfig> {
        vec![
            Self::llama_sim(),
            Self::gemma_sim(),
            Self::pythia_sim(PythiaSize::S),
            Self::pythia_sim(PythiaSize::M),
            Self::pythia_sim(PythiaSize::L),
        ]
    }

    pub fn by_name(name: &str) -> anyhow::Result<ModelConfig> {
        Self::all()
            .into_iter()
            .find(|c| c.name == name)
            .ok_or_else(|| anyhow::anyhow!("unknown model {name:?}"))
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("arch", Json::str(self.arch.as_str())),
            ("d_model", Json::Num(self.d_model as f64)),
            ("n_layers", Json::Num(self.n_layers as f64)),
            ("n_heads", Json::Num(self.n_heads as f64)),
            ("d_hidden", Json::Num(self.d_hidden as f64)),
            ("vocab", Json::Num(self.vocab as f64)),
            ("max_seq", Json::Num(self.max_seq as f64)),
            ("rope_theta", Json::Num(self.rope_theta as f64)),
            ("norm_eps", Json::Num(self.norm_eps as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        Ok(Self {
            name: j.get_str("name")?.to_string(),
            arch: Arch::parse(j.get_str("arch")?)?,
            d_model: j.get_usize("d_model")?,
            n_layers: j.get_usize("n_layers")?,
            n_heads: j.get_usize("n_heads")?,
            d_hidden: j.get_usize("d_hidden")?,
            vocab: j.get_usize("vocab")?,
            max_seq: j.get_usize("max_seq")?,
            rope_theta: j.get_f64("rope_theta")? as f32,
            norm_eps: j.get_f64("norm_eps")? as f32,
        })
    }
}

#[derive(Clone, Copy, Debug)]
pub enum PythiaSize {
    S,
    M,
    L,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_dim_divides() {
        for c in ModelConfig::all() {
            assert_eq!(c.d_model % c.n_heads, 0, "{}", c.name);
            assert_eq!(c.head_dim() % 2, 0, "{}: rope needs even head_dim", c.name);
        }
    }

    #[test]
    fn json_roundtrip() {
        for c in ModelConfig::all() {
            let j = c.to_json();
            let back = ModelConfig::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
            assert_eq!(back, c);
        }
    }

    #[test]
    fn param_counts_are_plausible() {
        let c = ModelConfig::llama_sim();
        let p = c.n_params();
        assert!(p > 1_000_000 && p < 4_000_000, "llama-sim params {p}");
        // pythia sizes are ordered
        let s = ModelConfig::pythia_sim(PythiaSize::S).n_params();
        let m = ModelConfig::pythia_sim(PythiaSize::M).n_params();
        let l = ModelConfig::pythia_sim(PythiaSize::L).n_params();
        assert!(s < m && m < l);
    }

    #[test]
    fn by_name_finds_all() {
        for c in ModelConfig::all() {
            assert_eq!(ModelConfig::by_name(&c.name).unwrap(), c);
        }
        assert!(ModelConfig::by_name("nope").is_err());
    }
}
