//! Paged decode path: batched decoding over the block-pool KV cache
//! (`crate::kvcache`), with shared-prefix reuse, memory-aware admission,
//! and preemption under pool pressure.
//!
//! [`decode_step_paged`] computes, row for row, exactly what
//! [`super::forward::decode_step_batch`] computes over dense caches — the
//! only change is KV row *addressing* (block chains into the shared pool,
//! attended by [`crate::tensor::attention_over_paged`]), so its logits are
//! bit-for-bit identical to the contiguous path and the dense cache stays
//! the test oracle (DESIGN.md §2b).
//!
//! [`PagedDecodeBatch`] is the paged sibling of [`super::DecodeBatch`]:
//! same join/step/retire schedule over a virtual token stream
//! `prompt ++ generated`, plus
//!
//! * **prefix reuse** — joins adopt the longest full-block prompt prefix
//!   from the [`PrefixTrie`] and skip prefill for those tokens entirely;
//!   completed prefills publish their full prompt blocks back to the trie;
//! * **memory-aware admission** — a join is admitted against the pool's
//!   free-block budget (after trying trie eviction), not just a slot count;
//! * **preemption** — when an append finds the pool exhausted mid-flight,
//!   trie eviction is tried first, then the youngest other live sequence
//!   releases its blocks and requeues (its refeed re-runs prefill, usually
//!   hitting the trie). Greedy decoding is deterministic, so preemption
//!   never changes a sequence's text.

use std::collections::VecDeque;

use super::config::ModelConfig;
use super::forward::{decode_step_body, BlockOps, FinishedSeq, SeqSpec, AMBIENT_BUDGET};
use super::ops;
use crate::flops::measured::{self, FlopPhases};
use crate::kvcache::{BlockPool, CacheError, PagedKvCache, PrefixTrie};
use crate::tensor::{attention_over_paged, Mat};
use crate::trace::{PhaseTotals, SeqBatchEvent, SEQ_EVENT_BUF_CAP};

/// One batched decode step over paged caches: row `r` of `tokens`/`seqs`
/// appends at its own position `seqs[r].len()`. Returns logits `[N, vocab]`
/// or a typed [`CacheError`] (positional capacity, or pool exhaustion from
/// the up-front block allocation) *before* any KV row is written.
pub fn decode_step_paged<B: BlockOps>(
    b: &B,
    tokens: &[u32],
    pool: &mut BlockPool,
    seqs: &mut [&mut PagedKvCache],
) -> Result<Mat, CacheError> {
    decode_step_paged_inner(b, tokens, pool, seqs, None)
}

/// [`decode_step_paged`] with a per-row compute budget (see
/// [`super::forward::decode_step_batch_budgeted`] — the budget threading is
/// identical on both cache layouts by construction).
pub fn decode_step_paged_budgeted<B: BlockOps>(
    b: &B,
    tokens: &[u32],
    pool: &mut BlockPool,
    seqs: &mut [&mut PagedKvCache],
    rates: &[f64],
) -> Result<Mat, CacheError> {
    assert_eq!(tokens.len(), rates.len(), "decode_step_paged_budgeted arity");
    decode_step_paged_inner(b, tokens, pool, seqs, Some(rates))
}

fn decode_step_paged_inner<B: BlockOps>(
    b: &B,
    tokens: &[u32],
    pool: &mut BlockPool,
    seqs: &mut [&mut PagedKvCache],
    rates: Option<&[f64]>,
) -> Result<Mat, CacheError> {
    assert_eq!(tokens.len(), seqs.len(), "decode_step_paged arity");
    let rows: Vec<(usize, u32)> = tokens.iter().copied().enumerate().collect();
    decode_step_paged_multi(b, &rows, pool, seqs, rates)
}

/// The paged sibling of `decode_step_batch_multi`: one batched pass where
/// a sequence may receive several successive tokens (the speculative
/// verify window). `rows[r] = (si, token)` feeds `token` to `seqs[si]` at
/// position `seqs[si].len() + (rows of si before r)`; a sequence's rows
/// must appear in stream order. Block allocation/COW for every target
/// position happens up front ([`PagedKvCache::prepare_append_n`]), so a
/// pool failure surfaces as a typed error *before* any KV row is written
/// (the error's `seq` names the offending row). Bit-for-bit identical to
/// the dense multi pass by the §2a/§2b construction — same per-layer body,
/// only KV addressing differs.
pub fn decode_step_paged_multi<B: BlockOps>(
    b: &B,
    rows: &[(usize, u32)],
    pool: &mut BlockPool,
    seqs: &mut [&mut PagedKvCache],
    rates: Option<&[f64]>,
) -> Result<Mat, CacheError> {
    let cfg = b.config().clone();
    let mut counts = vec![0usize; seqs.len()];
    let mut positions = Vec::with_capacity(rows.len());
    for &(si, _) in rows {
        let pos = seqs[si].len() + counts[si];
        if pos >= cfg.max_seq {
            return Err(CacheError::CacheFull { seq: positions.len(), pos, capacity: cfg.max_seq });
        }
        positions.push(pos);
        counts[si] += 1;
    }
    // Make every append target writable up front (block alloc + COW), so a
    // pool failure surfaces before any state is mutated. Idempotent for
    // callers (the batcher) that already prepared.
    for (si, s) in seqs.iter_mut().enumerate() {
        if counts[si] > 0 {
            s.prepare_append_n(pool, counts[si]).map_err(|e| {
                let first_row = rows.iter().position(|&(x, _)| x == si).expect("counted row");
                e.with_seq(first_row)
            })?;
        }
    }
    let tokens: Vec<u32> = rows.iter().map(|&(_, t)| t).collect();

    let bs = pool.block_size();
    let n_heads = cfg.n_heads;
    // Same per-layer body as the dense path — only the KV addressing in
    // this closure differs, which is what makes the paged logits
    // bit-for-bit identical to the contiguous oracle by construction.
    let logits = decode_step_body(b, &tokens, &positions, rates, |layer, r, q, k, v| {
        let si = rows[r].0;
        seqs[si].write_kv_at(pool, layer, positions[r], k, v);
        attention_over_paged(
            q,
            pool.layer_k(layer),
            pool.layer_v(layer),
            seqs[si].chain(),
            bs,
            positions[r] + 1,
            n_heads,
        )
    });
    for (si, s) in seqs.iter_mut().enumerate() {
        s.advance_n(counts[si]);
    }
    Ok(logits)
}

/// Sizing of a [`PagedDecodeBatch`].
#[derive(Clone, Copy, Debug)]
pub struct PagedBatchConfig {
    /// Tokens per KV block.
    pub block_size: usize,
    /// Total pool blocks; `0` → dense-equivalent memory
    /// (`slots × ⌈max_seq / block_size⌉`).
    pub n_blocks: usize,
    /// Maximum in-flight sequences per engine pass.
    pub slots: usize,
}

impl Default for PagedBatchConfig {
    fn default() -> Self {
        Self { block_size: 16, n_blocks: 0, slots: 8 }
    }
}

/// State of one in-flight sequence. `fed` indexes the virtual token stream
/// `prompt ++ generated`, so a preempted sequence simply resets `fed` and
/// re-runs prefill over everything it had already committed to.
struct PagedSeqState {
    id: u64,
    prompt: Vec<u32>,
    fed: usize,
    n_gen: usize,
    sampling: ops::Sampling,
    rng: crate::util::rng::Xoshiro256,
    budget: Option<f64>,
    /// Speculative decoding state (`None` = plain decoding). The paged
    /// path needs no pending-token slot: a corrected token lands in
    /// `generated` without advancing `fed`, so the virtual stream feeds it
    /// on the next pass (and preemption refeeds replay it for free).
    spec: Option<super::forward::SpecSeq>,
    generated: Vec<u32>,
    last_logits: Vec<f32>,
    cache: PagedKvCache,
    done: bool,
    /// Prompt's full blocks have been published to the trie.
    prompt_in_trie: bool,
    /// Measured FLOPs attributed to this sequence (its share of every
    /// engine pass it rode, split proportionally by row count).
    flops: u64,
}

impl PagedSeqState {
    fn stream_len(&self) -> usize {
        self.prompt.len() + self.generated.len()
    }

    fn stream_tok(&self, i: usize) -> u32 {
        if i < self.prompt.len() {
            self.prompt[i]
        } else {
            self.generated[i - self.prompt.len()]
        }
    }
}

/// Iteration-level batched greedy decoder over a shared [`BlockPool`] —
/// the paged replacement for [`super::DecodeBatch`] (see module docs).
pub struct PagedDecodeBatch {
    cfg: ModelConfig,
    pool: BlockPool,
    trie: PrefixTrie,
    slots: Vec<Option<PagedSeqState>>,
    /// Preempted sequences awaiting re-admission (front = oldest).
    preempted: VecDeque<PagedSeqState>,
    /// Tokens generated since the last [`PagedDecodeBatch::drain_emitted`].
    emitted: Vec<(u64, u32)>,
    /// Sequences cancelled while preempted (no slot to retire from).
    finished_aside: Vec<FinishedSeq>,
    next_id: u64,
    /// Speculation defaults (draft length, draft budget) for joins.
    spec: crate::spec::SpecConfig,
    /// Stream tokens fed per sequence per engine pass during prefill /
    /// refeed (chunked prefill, DESIGN.md §2h). 1 = the legacy
    /// one-token-per-pass interleave; larger chunks cut a length-L prefill
    /// to ⌈L/C⌉ passes with bitwise-identical outputs and trie blocks.
    prefill_chunk: usize,
    /// Tokens fed across all steps (batch-occupancy accounting; committed
    /// tokens only — rolled-back draft/verify rows are not counted here).
    pub tokens_processed: u64,
    /// Engine passes executed.
    pub steps: u64,
    /// Prompt tokens whose prefill was skipped via trie hits.
    pub prefix_hit_tokens: u64,
    /// Sequences preempted (blocks released, requeued) under pool pressure.
    pub preemptions: u64,
    /// Draft tokens proposed by speculation rounds.
    pub draft_tokens: u64,
    /// Draft tokens that survived full-budget verification.
    pub accepted_tokens: u64,
    /// Speculation rounds that rolled the cache back (some draft rejected).
    pub spec_rollbacks: u64,
    /// Wall-clock split of the engine passes (timing only — never read by
    /// the schedule).
    phases: PhaseTotals,
    /// Measured FLOP/byte split of the engine passes, attributed to phases
    /// by the same row-kind rule as `phases` (observability only).
    flops: FlopPhases,
    /// Structural per-sequence events since the last drain (prefill chunks,
    /// spec rounds, preempt/readmit), bounded by [`SEQ_EVENT_BUF_CAP`].
    seq_events: Vec<(u64, SeqBatchEvent)>,
}

impl PagedDecodeBatch {
    pub fn new(cfg: &ModelConfig, pc: PagedBatchConfig) -> Self {
        let slots = pc.slots.max(1);
        let block_size = pc.block_size.max(1);
        let dense_equiv = slots * cfg.max_seq.div_ceil(block_size);
        let n_blocks = if pc.n_blocks == 0 { dense_equiv } else { pc.n_blocks };
        Self {
            cfg: cfg.clone(),
            pool: BlockPool::new(cfg, block_size, n_blocks),
            trie: PrefixTrie::new(),
            slots: (0..slots).map(|_| None).collect(),
            preempted: VecDeque::new(),
            emitted: Vec::new(),
            finished_aside: Vec::new(),
            next_id: 0,
            spec: crate::spec::SpecConfig::default(),
            prefill_chunk: 1,
            tokens_processed: 0,
            steps: 0,
            prefix_hit_tokens: 0,
            preemptions: 0,
            draft_tokens: 0,
            accepted_tokens: 0,
            spec_rollbacks: 0,
            phases: PhaseTotals::default(),
            flops: FlopPhases::default(),
            seq_events: Vec::new(),
        }
    }

    /// Configure speculation defaults for sequences joined from now on.
    pub fn set_spec(&mut self, spec: crate::spec::SpecConfig) {
        self.spec = spec;
    }

    /// Stream tokens fed per sequence per prefill/refeed pass (clamped to
    /// ≥ 1). Chunked and monolithic prefill are bitwise-equivalent,
    /// including the prefix-trie blocks a completed prefill publishes.
    pub fn set_prefill_chunk(&mut self, chunk: usize) {
        self.prefill_chunk = chunk.max(1);
    }

    /// `(draft_tokens, accepted_tokens, spec_rollbacks)` running totals.
    pub fn spec_stats(&self) -> (u64, u64, u64) {
        (self.draft_tokens, self.accepted_tokens, self.spec_rollbacks)
    }

    /// Running per-phase wall-clock totals (sessions report deltas upward).
    pub fn phase_stats(&self) -> PhaseTotals {
        self.phases
    }

    /// Running per-phase measured FLOP/byte totals (sessions report deltas
    /// upward, mirroring [`PagedDecodeBatch::phase_stats`]).
    pub fn flop_stats(&self) -> FlopPhases {
        self.flops
    }

    /// Structural per-sequence events since the last drain.
    pub fn drain_seq_events(&mut self) -> Vec<(u64, SeqBatchEvent)> {
        std::mem::take(&mut self.seq_events)
    }

    /// Put drained-but-foreign events back at the front (shared-batch
    /// sessions return other sessions' events, like
    /// [`PagedDecodeBatch::restore_emitted`]).
    pub fn restore_seq_events(&mut self, mut items: Vec<(u64, SeqBatchEvent)>) {
        items.extend(std::mem::take(&mut self.seq_events));
        self.seq_events = items;
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Sequences currently admitted, awaiting re-admission, or finished
    /// aside (all still owe their caller a result).
    pub fn active(&self) -> usize {
        self.slots.iter().flatten().count() + self.preempted.len() + self.finished_aside.len()
    }

    pub fn has_work(&self) -> bool {
        self.slots.iter().flatten().any(|s| !s.done) || !self.preempted.is_empty()
    }

    pub fn pool(&self) -> &BlockPool {
        &self.pool
    }

    /// Pool snapshot for the serving metrics:
    /// `(blocks_in_use, blocks_peak, prefix_hit_tokens, preemptions)`.
    pub fn kv_stats(&self) -> (usize, usize, u64, u64) {
        (
            self.pool.blocks_in_use(),
            self.pool.blocks_peak(),
            self.prefix_hit_tokens,
            self.preemptions,
        )
    }

    fn live_count(&self) -> usize {
        self.slots.iter().flatten().filter(|s| !s.done).count()
    }

    /// Admit `st` against the free-block budget: adopt the longest shared
    /// prompt prefix from the trie, then require the sequence's whole
    /// remaining run to fit in free blocks (after trying trie eviction).
    /// `force` overrides the budget when nothing else is in flight, so one
    /// sequence always makes progress.
    fn admit(&mut self, st: &mut PagedSeqState, force: bool) -> bool {
        let bs = self.pool.block_size();
        // At least one stream token must remain to feed (its logits seed
        // generation), and only prompt tokens live in the trie. Sequences
        // carrying a per-request budget override bypass the trie entirely:
        // KV computed at one compute budget must never seed decoding at
        // another.
        let reusable = st.stream_len().saturating_sub(1).min(st.prompt.len());
        let chain = if st.budget.is_some() {
            Vec::new()
        } else {
            self.trie.lookup(&st.prompt, reusable / bs, &mut self.pool)
        };
        let matched = chain.len() * bs;
        // Optimistic (vLLM-style) budget: the stream already committed plus
        // one generated token must fit *now*; later decode growth is served
        // lazily and handled by eviction/preemption when the pool runs dry.
        let total = (st.stream_len() + 1).min(self.cfg.max_seq);
        let needed = self.pool.blocks_for(total).saturating_sub(chain.len());
        if self.pool.free_blocks() < needed {
            let short = needed - self.pool.free_blocks();
            self.trie.evict(&mut self.pool, short);
        }
        if self.pool.free_blocks() < needed && !force {
            for &b in &chain {
                self.pool.release(b);
            }
            return false;
        }
        self.prefix_hit_tokens += matched as u64;
        st.cache = PagedKvCache::from_shared_prefix(chain, matched, bs);
        st.fed = matched;
        true
    }

    /// Admit a sequence; `None` when every slot is occupied **or** the
    /// free-block budget refuses the join (retry after steps retire or
    /// preemption frees blocks).
    pub fn try_join(&mut self, prompt: Vec<u32>, n_gen: usize) -> Option<u64> {
        self.try_join_spec(SeqSpec::greedy(prompt, n_gen))
    }

    /// Admit a sequence with explicit sampling params and budget override.
    pub fn try_join_spec(&mut self, spec: SeqSpec) -> Option<u64> {
        let speculation = super::forward::SpecSeq::for_join(&self.spec, spec.spec_k);
        let slot_idx = self.slots.iter().position(|s| s.is_none())?;
        let done = spec.prompt.is_empty();
        let mut st = PagedSeqState {
            id: 0,
            prompt: spec.prompt,
            fed: 0,
            n_gen: spec.max_new,
            rng: crate::util::rng::Xoshiro256::new(spec.sampling.seed),
            sampling: spec.sampling,
            budget: spec.budget,
            spec: speculation,
            generated: Vec::new(),
            last_logits: Vec::new(),
            cache: PagedKvCache::new(),
            done,
            prompt_in_trie: false,
            flops: 0,
        };
        if !done {
            let force = self.live_count() == 0 && self.preempted.is_empty();
            if !self.admit(&mut st, force) {
                return None;
            }
        }
        st.id = self.next_id;
        self.next_id += 1;
        let id = st.id;
        self.slots[slot_idx] = Some(st);
        Some(id)
    }

    fn finish(pool: &mut BlockPool, s: &mut PagedSeqState) {
        s.done = true;
        s.cache.release(pool);
    }

    /// Mark a sequence finished where it stands (client cancel), releasing
    /// its blocks; its partial result is returned by the next retire. A
    /// preempted sequence is retired from the side queue. Returns false
    /// for unknown ids.
    pub fn cancel(&mut self, id: u64) -> bool {
        for s in self.slots.iter_mut().flatten() {
            if s.id == id {
                if !s.done {
                    Self::finish(&mut self.pool, s);
                }
                return true;
            }
        }
        if let Some(p) = self.preempted.iter().position(|s| s.id == id) {
            // Blocks were already released at preemption time.
            let s = self.preempted.remove(p).expect("checked position");
            self.finished_aside.push(FinishedSeq {
                id: s.id,
                prompt: s.prompt,
                generated: s.generated,
                flops: s.flops,
            });
            return true;
        }
        false
    }

    /// Tokens generated since the last drain, in generation order.
    pub fn drain_emitted(&mut self) -> Vec<(u64, u32)> {
        std::mem::take(&mut self.emitted)
    }

    /// Put drained-but-unconsumed tokens back at the front of the stream
    /// (a session on the shared batch returns other sessions' deltas).
    pub fn restore_emitted(&mut self, mut items: Vec<(u64, u32)>) {
        items.extend(std::mem::take(&mut self.emitted));
        self.emitted = items;
    }

    /// Drop every shared-prefix entry. Called on shared-budget retunes:
    /// trie blocks hold KV computed at the old budget, which must not seed
    /// prefills at the new one. In-flight sequences are barred from
    /// publishing too — a prefill straddling the retune holds
    /// mixed-budget KV in its private chain, which must stay private.
    pub fn flush_prefix_cache(&mut self) {
        self.trie.clear(&mut self.pool);
        for s in self.slots.iter_mut().flatten() {
            s.prompt_in_trie = true;
        }
    }

    /// Youngest live sequence other than slot `except` (preemption victim).
    fn youngest_other_live(&self, except: usize) -> Option<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(i, s)| *i != except && s.as_ref().map(|s| !s.done).unwrap_or(false))
            .max_by_key(|(_, s)| s.as_ref().map(|s| s.id).unwrap_or(0))
            .map(|(i, _)| i)
    }

    /// One engine pass; returns how many sequences advanced. Handles
    /// re-admission of preempted sequences, per-sequence block preparation
    /// with eviction/preemption under pool pressure, the batched paged
    /// forward (including speculative draft/verify rounds, DESIGN.md §2d),
    /// and trie publication of completed prefills.
    pub fn step<B: BlockOps>(&mut self, b: &B) -> usize {
        let max_seq = self.cfg.max_seq;
        let bs = self.pool.block_size();

        // 1. Re-admit preempted sequences into free slots, oldest first.
        let t_readmit = std::time::Instant::now();
        while let Some(free_idx) = self.slots.iter().position(|s| s.is_none()) {
            let Some(mut st) = self.preempted.pop_front() else { break };
            let force = self.live_count() == 0;
            if self.admit(&mut st, force) {
                if self.seq_events.len() < SEQ_EVENT_BUF_CAP {
                    self.seq_events.push((st.id, SeqBatchEvent::Readmit));
                }
                self.slots[free_idx] = Some(st);
            } else {
                self.preempted.push_front(st);
                break;
            }
        }
        self.phases.maintenance_us += t_readmit.elapsed().as_micros() as u64;

        // 2. Token selection over the virtual stream (same schedule as the
        // dense DecodeBatch; `fed` resets on preemption). A generation-
        // phase selection may open a speculation round (`k > 0`); `base`
        // is the rollback target.
        struct Plan {
            idx: usize,
            /// Tokens this sequence feeds this pass: one prefill/refeed
            /// chunk (stream order) or a single generation-phase token.
            toks: Vec<u32>,
            k: usize,
            base: usize,
            /// Stream-feed row (prompt prefill or preemption refeed) —
            /// timing attribution only.
            prefill: bool,
        }
        let mut plan: Vec<Plan> = Vec::new();
        for idx in 0..self.slots.len() {
            let Some(s) = self.slots[idx].as_mut() else { continue };
            if s.done {
                continue;
            }
            if s.cache.len() >= max_seq {
                // Over-long prompt: truncate prefill rather than overflow
                // (same truncation point as the dense batch: exactly
                // max_seq stream tokens enter the cache, zero generated).
                Self::finish(&mut self.pool, s);
                continue;
            }
            let (toks, gen_phase) = if s.fed < s.stream_len() {
                // A backlog of exactly one generated token — the corrected
                // token of a rejected round — may speculate onward; prompt
                // prefill and deeper refeed backlogs are fed as plain
                // chunks of up to `prefill_chunk` stream tokens, clamped
                // to the backlog and the positional capacity
                // (cache.len() < max_seq was checked above).
                let rem = s.stream_len() - s.fed;
                let gen_single =
                    rem == 1 && s.fed + 1 > s.prompt.len() && !s.last_logits.is_empty();
                if gen_single {
                    let t = s.stream_tok(s.fed);
                    s.fed += 1;
                    (vec![t], true)
                } else {
                    let chunk = self
                        .prefill_chunk
                        .min(rem)
                        .min(max_seq - s.cache.len())
                        .max(1);
                    let toks: Vec<u32> =
                        (s.fed..s.fed + chunk).map(|i| s.stream_tok(i)).collect();
                    s.fed += chunk;
                    (toks, false)
                }
            } else if s.generated.len() >= s.n_gen {
                Self::finish(&mut self.pool, s);
                continue;
            } else if s.cache.len() + 1 >= max_seq {
                Self::finish(&mut self.pool, s);
                continue;
            } else {
                let next = ops::sample_token(&s.last_logits, &s.sampling, &mut s.rng);
                s.generated.push(next);
                self.emitted.push((s.id, next));
                if s.generated.len() >= s.n_gen {
                    // Final token: recorded, needs no engine pass.
                    Self::finish(&mut self.pool, s);
                    continue;
                }
                s.fed += 1;
                (vec![next], true)
            };
            // Draft length: the controller's pick, clamped so accepted
            // drafts can neither exceed the request nor the positional
            // capacity. Plain decode refuses to sample once
            // `len + 1 >= max_seq`, so draft d_i (sampled at len base + i)
            // is only emittable while `base + i + 1 < max_seq`: k caps at
            // `max_seq - base - 2` — one tighter than the feed capacity —
            // or the speculative stream would outrun the plain one at the
            // cache boundary.
            let k = if gen_phase {
                s.spec
                    .as_ref()
                    .map(|sp| {
                        sp.ctrl
                            .k()
                            .min(s.n_gen.saturating_sub(s.generated.len()))
                            .min(max_seq.saturating_sub(s.cache.len() + 2))
                    })
                    .unwrap_or(0)
            } else {
                0
            };
            plan.push(Plan { idx, toks, k, base: s.cache.len(), prefill: !gen_phase });
        }

        // 2b. Draft phase: low-budget passes batched across speculating
        // sequences; pass j feeds x0 (j = 0) or d_j, its logits propose
        // d_{j+1}. Pool pressure degrades a sequence's round gracefully.
        let mut drafts: Vec<Vec<u32>> = (0..plan.len()).map(|_| Vec::new()).collect();
        let mut dists: Vec<crate::spec::DraftDists> =
            (0..plan.len()).map(|_| Vec::new()).collect();
        if plan.iter().any(|p| p.k > 0) {
            let t_draft = std::time::Instant::now();
            let f_draft0 = measured::enabled().then(measured::snapshot);
            let draft_rate = self.spec.draft_rate;
            let mut j = 0;
            loop {
                let active: Vec<usize> = (0..plan.len()).filter(|&p| plan[p].k > j).collect();
                if active.is_empty() {
                    break;
                }
                let tokens: Vec<u32> = active
                    .iter()
                    // k > 0 only on generation-phase rows, whose `toks` is
                    // the single token x0 the draft round starts from.
                    .map(|&p| if j == 0 { plan[p].toks[0] } else { drafts[p][j - 1] })
                    .collect();
                let rates: Vec<f64> = vec![draft_rate; active.len()];
                let res = {
                    let mut seq_refs: Vec<&mut PagedKvCache> = Vec::with_capacity(active.len());
                    let mut want = active.iter().map(|&p| plan[p].idx).peekable();
                    for (idx, slot) in self.slots.iter_mut().enumerate() {
                        if want.peek() == Some(&idx) {
                            want.next();
                            seq_refs
                                .push(&mut slot.as_mut().expect("planned slot occupied").cache);
                        }
                    }
                    decode_step_paged_inner(b, &tokens, &mut self.pool, &mut seq_refs, Some(&rates))
                };
                let logits = match res {
                    Ok(l) => l,
                    Err(e) => {
                        // Pool pressure mid-draft: keep the drafts already
                        // proposed for the offending sequence and move on —
                        // speculation degrades, correctness is unaffected.
                        let p = active[e.seq().min(active.len() - 1)];
                        plan[p].k = drafts[p].len();
                        continue;
                    }
                };
                for (r, &p) in active.iter().enumerate() {
                    let s = self.slots[plan[p].idx].as_mut().expect("planned slot occupied");
                    let row = logits.row(r);
                    let d = ops::sample_token(row, &s.sampling, &mut s.rng);
                    if !s.sampling.is_greedy() {
                        dists[p].push(ops::sampling_dist(row, &s.sampling));
                    }
                    drafts[p].push(d);
                }
                j += 1;
            }
            // Roll every draft append back: draft KV is low-budget KV and
            // must never seed a full-budget context (blocks return to the
            // pool; shared prefix blocks only lose this chain's refs).
            for p in &plan {
                if p.k > 0 {
                    let s = self.slots[p.idx].as_mut().expect("planned slot occupied");
                    s.cache.truncate(&mut self.pool, p.base);
                }
            }
            self.phases.spec_draft_us += t_draft.elapsed().as_micros() as u64;
            if let Some(base) = f_draft0 {
                // Draft-phase measured compute; per-sequence shares split
                // proportionally by draft length (u128 to avoid overflow).
                let delta = measured::snapshot().delta_since(&base);
                self.flops.draft += delta;
                let total_k: u64 = plan.iter().map(|p| p.k as u64).sum();
                if total_k > 0 && delta.flops > 0 {
                    for p in &plan {
                        if p.k == 0 {
                            continue;
                        }
                        let share =
                            (delta.flops as u128 * p.k as u128 / total_k as u128) as u64;
                        if let Some(s) = self.slots[p.idx].as_mut() {
                            s.flops += share;
                        }
                    }
                }
            }
        }

        // 3. Prepare every append window (alloc/COW): toks + k positions
        // for a speculation round, the chunk length for a prefill row. On
        // exhaustion the ladder is: degrade the round to a plain append,
        // evict trie-only blocks, shrink the prefill chunk to one token
        // (today's footprint), preempt the youngest other live sequence;
        // a sequence the pool cannot hold even alone is truncated.
        let t_prepare = std::time::Instant::now();
        let mut i = 0;
        while i < plan.len() {
            let idx = plan[i].idx;
            let need = plan[i].toks.len() + plan[i].k;
            let res = self.slots[idx]
                .as_mut()
                .expect("planned slot occupied")
                .cache
                .prepare_append_n(&mut self.pool, need);
            match res {
                Ok(()) => i += 1,
                Err(_) => {
                    if plan[i].k > 0 {
                        // Shrink this sequence's own footprint before
                        // taking blocks from anyone else.
                        plan[i].k = 0;
                        drafts[i].clear();
                        dists[i].clear();
                        continue;
                    }
                    if self.trie.evict(&mut self.pool, 1) > 0 {
                        continue; // retry this sequence
                    }
                    if plan[i].toks.len() > 1 {
                        // Pool pressure degrades chunked prefill back to
                        // the one-token-per-pass interleave: return the
                        // unfed tail to the stream backlog and retry.
                        let s = self.slots[idx].as_mut().expect("planned slot occupied");
                        s.fed -= plan[i].toks.len() - 1;
                        plan[i].toks.truncate(1);
                        continue;
                    }
                    match self.youngest_other_live(idx) {
                        Some(v) => {
                            let mut st = self.slots[v].take().expect("victim occupied");
                            st.cache.release(&mut self.pool);
                            st.fed = 0;
                            st.prompt_in_trie = false;
                            self.preemptions += 1;
                            if self.seq_events.len() < SEQ_EVENT_BUF_CAP {
                                self.seq_events.push((st.id, SeqBatchEvent::Preempt));
                            }
                            self.preempted.push_back(st);
                            if let Some(q) = plan.iter().position(|p| p.idx == v) {
                                if q < i {
                                    i -= 1;
                                }
                                plan.remove(q);
                                drafts.remove(q);
                                dists.remove(q);
                            }
                        }
                        None => {
                            let s = self.slots[idx].as_mut().expect("planned slot occupied");
                            Self::finish(&mut self.pool, s);
                            plan.remove(i);
                            drafts.remove(i);
                            dists.remove(i);
                        }
                    }
                }
            }
        }
        self.phases.maintenance_us += t_prepare.elapsed().as_micros() as u64;

        // 4. One full-budget paged pass over all rows: plain rows feed one
        // token, speculating rows feed x0 + their drafts. CacheErrors are
        // unreachable after the guards above, but the contract stands: the
        // offending sequence retires; the pass retries with the rest.
        let t_pass = std::time::Instant::now();
        let f_pass0 = measured::enabled().then(measured::snapshot);
        let logits = loop {
            if plan.is_empty() {
                return 0;
            }
            let mut rows: Vec<(usize, u32)> = Vec::new();
            for (si, p) in plan.iter().enumerate() {
                for &t in &p.toks {
                    rows.push((si, t));
                }
                for &d in &drafts[si][..p.k] {
                    rows.push((si, d));
                }
            }
            // Per-row budgets only when some sequence carries an override
            // (all-ambient batches keep the legacy call).
            let rates: Option<Vec<f64>> = plan
                .iter()
                .any(|p| self.slots[p.idx].as_ref().is_some_and(|s| s.budget.is_some()))
                .then(|| {
                    rows.iter()
                        .map(|&(si, _)| {
                            self.slots[plan[si].idx]
                                .as_ref()
                                .and_then(|s| s.budget)
                                .unwrap_or(AMBIENT_BUDGET)
                        })
                        .collect()
                });
            let res = {
                let mut seq_refs: Vec<&mut PagedKvCache> = Vec::with_capacity(plan.len());
                let mut want = plan.iter().map(|p| p.idx).peekable();
                for (idx, slot) in self.slots.iter_mut().enumerate() {
                    if want.peek() == Some(&idx) {
                        want.next();
                        seq_refs.push(&mut slot.as_mut().expect("planned slot occupied").cache);
                    }
                }
                decode_step_paged_multi(b, &rows, &mut self.pool, &mut seq_refs, rates.as_deref())
            };
            match res {
                Ok(l) => break l,
                Err(e) => {
                    let row = e.seq().min(rows.len() - 1);
                    let si = rows[row].0;
                    let s = self.slots[plan[si].idx].as_mut().expect("planned slot occupied");
                    Self::finish(&mut self.pool, s);
                    plan.remove(si);
                    drafts.remove(si);
                    dists.remove(si);
                }
            }
        };
        {
            // Split the shared pass across prefill / decode / verify rows by
            // row count — timing attribution only, no compute branch.
            let pass_us = t_pass.elapsed().as_micros() as u64;
            let prefill_rows: u64 =
                plan.iter().filter(|p| p.prefill).map(|p| p.toks.len() as u64).sum();
            let verify_rows: u64 = plan.iter().map(|p| p.k as u64).sum();
            let decode_rows = plan.iter().filter(|p| !p.prefill).count() as u64;
            self.phases.attribute_pass(pass_us, prefill_rows, decode_rows, verify_rows);
            if let Some(base) = f_pass0 {
                // Measured compute of the shared pass: same row-kind split
                // as the timing above, plus per-sequence shares by row count.
                let delta = measured::snapshot().delta_since(&base);
                self.flops.attribute_pass(delta, prefill_rows, decode_rows, verify_rows);
                let total_rows: u64 =
                    plan.iter().map(|p| (p.toks.len() + p.k) as u64).sum();
                if total_rows > 0 && delta.flops > 0 {
                    for p in &plan {
                        let share = (delta.flops as u128
                            * (p.toks.len() + p.k) as u128
                            / total_rows as u128) as u64;
                        if let Some(s) = self.slots[p.idx].as_mut() {
                            s.flops += share;
                        }
                    }
                }
            }
        }

        // 5. Publish completed prefills' full prompt blocks; record logits
        // and settle speculation rounds (accept prefix, roll back the
        // rejected tail).
        let mut committed = 0u64;
        let mut cursor = 0usize;
        for (si, p) in plan.iter().enumerate() {
            let s = self.slots[p.idx].as_mut().expect("planned slot occupied");
            if s.budget.is_some() {
                // Budget-overridden KV stays private (see `admit`).
                s.prompt_in_trie = true;
            }
            if !s.prompt_in_trie && s.cache.len() >= s.prompt.len() {
                let n_full = s.prompt.len() / bs;
                if n_full > 0 {
                    self.trie.insert(&s.prompt, &s.cache.chain()[..n_full], &mut self.pool);
                }
                s.prompt_in_trie = true;
            }
            if p.k == 0 {
                // The held logits are the final fed row's — for a chunk
                // that is the logits after its last stream token, exactly
                // what the one-token-per-pass interleave would have held.
                // The Prefill event is recorded here (not at selection) so
                // it reflects the chunk size that actually ran after any
                // pool-pressure shrink in the prepare ladder.
                if p.prefill && self.seq_events.len() < SEQ_EVENT_BUF_CAP {
                    self.seq_events
                        .push((s.id, SeqBatchEvent::Prefill { tokens: p.toks.len() as u32 }));
                }
                s.last_logits = logits.row(cursor + p.toks.len() - 1).to_vec();
                committed += p.toks.len() as u64;
                cursor += p.toks.len();
                continue;
            }
            let verify: Vec<&[f32]> = (0..=p.k).map(|r| logits.row(cursor + r)).collect();
            let out = crate::spec::accept_drafts(
                &drafts[si][..p.k],
                &dists[si],
                &verify,
                &s.sampling,
                &mut s.rng,
            );
            let a = out.accepted;
            self.draft_tokens += p.k as u64;
            self.accepted_tokens += a as u64;
            if self.seq_events.len() < SEQ_EVENT_BUF_CAP {
                self.seq_events.push((
                    s.id,
                    SeqBatchEvent::SpecRound { drafted: p.k as u32, accepted: a as u32 },
                ));
            }
            committed += 1 + a as u64;
            for &d in &drafts[si][..a] {
                s.generated.push(d);
                self.emitted.push((s.id, d));
                s.fed += 1;
            }
            if a < p.k {
                // Rejected tail: whole blocks past the accepted prefix
                // return to the pool; the published-prefix boundary is
                // never crossed (base >= prompt length in a generation
                // round).
                self.spec_rollbacks += 1;
                debug_assert!(p.base >= s.prompt.len().min(max_seq));
                s.cache.truncate(&mut self.pool, p.base + 1 + a);
                s.last_logits = logits.row(cursor + a).to_vec();
                if s.generated.len() >= s.n_gen || s.cache.len() + 1 >= max_seq {
                    Self::finish(&mut self.pool, s);
                } else {
                    let c = out.corrected.expect("rejection carries a corrected token");
                    s.generated.push(c);
                    self.emitted.push((s.id, c));
                    // `fed` stays put: the virtual stream feeds c next pass.
                    if s.generated.len() >= s.n_gen {
                        Self::finish(&mut self.pool, s);
                    }
                }
            } else {
                // Full acceptance: the bonus row V_k seeds the next round.
                s.last_logits = logits.row(cursor + p.k).to_vec();
                if s.generated.len() >= s.n_gen {
                    Self::finish(&mut self.pool, s);
                }
            }
            if let Some(sp) = s.spec.as_mut() {
                sp.ctrl.observe(p.k, a);
            }
            cursor += 1 + p.k;
        }
        let n = plan.len();
        self.steps += 1;
        self.tokens_processed += committed;
        n
    }

    /// Remove finished sequences, freeing their slots (their blocks were
    /// already released at finish time).
    pub fn retire_finished(&mut self) -> Vec<FinishedSeq> {
        self.retire_finished_owned(|_| true)
    }

    /// Like [`PagedDecodeBatch::retire_finished`], but only for sequences
    /// whose id satisfies `owned`. An engine-persistent batch can host
    /// sequences admitted by several sessions; each session retires only
    /// its own, leaving the rest in their slots for their owners.
    pub fn retire_finished_owned(&mut self, owned: impl Fn(u64) -> bool) -> Vec<FinishedSeq> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.finished_aside.len() {
            if owned(self.finished_aside[i].id) {
                out.push(self.finished_aside.swap_remove(i));
            } else {
                i += 1;
            }
        }
        for slot in &mut self.slots {
            if slot.as_ref().map(|s| s.done && owned(s.id)).unwrap_or(false) {
                let s = slot.take().expect("checked above");
                out.push(FinishedSeq {
                    id: s.id,
                    prompt: s.prompt,
                    generated: s.generated,
                    flops: s.flops,
                });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::Arch;
    use crate::model::forward::{decode_step, decode_step_batch, KvCache, Model};
    use crate::model::weights::ModelWeights;

    fn tiny_cfg(arch: Arch) -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            arch,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_hidden: 32,
            vocab: 64,
            max_seq: 32,
            rope_theta: 10_000.0,
            norm_eps: 1e-5,
        }
    }

    fn tiny_model(arch: Arch) -> Model {
        let cfg = tiny_cfg(arch);
        let w = ModelWeights::random_init(&cfg, 11);
        Model::new(cfg, w).unwrap()
    }

    #[test]
    fn paged_step_bitwise_matches_dense_batch() {
        for arch in [Arch::SwiGlu, Arch::GeluNeoX] {
            let m = tiny_model(arch);
            for &bs in &[1usize, 7, 16] {
                let mut pool = BlockPool::new(&m.cfg, bs, 64);
                let streams: Vec<Vec<u32>> =
                    vec![vec![1, 5, 9, 30, 2, 17], vec![8, 8, 1, 0, 63, 2]];
                let mut dense: Vec<KvCache> =
                    streams.iter().map(|_| KvCache::new(&m.cfg)).collect();
                let mut paged: Vec<PagedKvCache> =
                    streams.iter().map(|_| PagedKvCache::new()).collect();
                for t in 0..streams[0].len() {
                    let toks: Vec<u32> = streams.iter().map(|s| s[t]).collect();
                    let mut drefs: Vec<&mut KvCache> = dense.iter_mut().collect();
                    let want = decode_step_batch(&m, &toks, &mut drefs).unwrap();
                    let mut prefs: Vec<&mut PagedKvCache> = paged.iter_mut().collect();
                    let got = decode_step_paged(&m, &toks, &mut pool, &mut prefs).unwrap();
                    assert_eq!(got.data, want.data, "arch {arch:?} bs {bs} step {t}");
                }
                for mut p in paged {
                    p.release(&mut pool);
                }
                assert_eq!(pool.free_blocks(), 64);
            }
        }
    }

    #[test]
    fn paged_batch_reproduces_dense_batch_texts() {
        let m = tiny_model(Arch::SwiGlu);
        let prompts: Vec<(Vec<u32>, usize)> =
            vec![(vec![1, 2, 3], 4), (vec![4, 5], 3), (vec![9, 9, 9, 9], 2)];
        // Dense oracle.
        let mut dense = super::super::forward::DecodeBatch::new(&m.cfg, 3);
        for (p, n) in &prompts {
            dense.try_join(p.clone(), *n).unwrap();
        }
        let mut want = Vec::new();
        while dense.has_work() {
            dense.step(&m);
            want.extend(dense.retire_finished());
        }
        want.extend(dense.retire_finished());
        want.sort_by_key(|f| f.prompt.clone());
        // Paged, small blocks.
        let mut paged = PagedDecodeBatch::new(
            &m.cfg,
            PagedBatchConfig { block_size: 2, n_blocks: 0, slots: 3 },
        );
        for (p, n) in &prompts {
            paged.try_join(p.clone(), *n).unwrap();
        }
        let mut got = Vec::new();
        let mut guard = 0;
        while paged.has_work() {
            paged.step(&m);
            got.extend(paged.retire_finished());
            guard += 1;
            assert!(guard < 128, "paged batch failed to converge");
        }
        got.extend(paged.retire_finished());
        got.sort_by_key(|f| f.prompt.clone());
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.prompt, w.prompt);
            assert_eq!(g.generated, w.generated, "paged text diverged from dense oracle");
        }
        // All blocks returned (trie may retain prompt blocks).
        assert_eq!(
            paged.pool.blocks_in_use(),
            paged.trie.blocks_held(),
            "retired sequences must only leave trie-held blocks"
        );
    }

    #[test]
    fn shared_prefix_skips_prefill_and_matches_solo_decode() {
        let m = tiny_model(Arch::SwiGlu);
        let prefix: Vec<u32> = (0..8).map(|i| (i * 3 + 1) % 60).collect();
        let mk = |tail: &[u32]| {
            let mut p = prefix.clone();
            p.extend_from_slice(tail);
            p
        };
        let mut paged = PagedDecodeBatch::new(
            &m.cfg,
            PagedBatchConfig { block_size: 4, n_blocks: 0, slots: 2 },
        );
        // First request warms the trie.
        paged.try_join(mk(&[7]), 3).unwrap();
        while paged.has_work() {
            paged.step(&m);
        }
        let first = paged.retire_finished();
        assert_eq!(first.len(), 1);
        assert_eq!(paged.prefix_hit_tokens, 0, "cold trie cannot hit");
        assert!(paged.trie.blocks_held() > 0, "completed prefill must publish blocks");

        // Second request with the same 8-token prefix: 2 full blocks reused.
        paged.try_join(mk(&[50, 51]), 3).unwrap();
        while paged.has_work() {
            paged.step(&m);
        }
        let second = paged.retire_finished();
        assert_eq!(paged.prefix_hit_tokens, 8, "2 full blocks of 4 must be reused");
        // Reused-prefix decode must equal an isolated sequential decode.
        let mut cache = KvCache::new(&m.cfg);
        let mut logits = Vec::new();
        for &t in &mk(&[50, 51]) {
            logits = decode_step(&m, t, &mut cache).unwrap();
        }
        let mut want = Vec::new();
        for _ in 0..3 {
            let next = crate::eval::argmax(&logits) as u32;
            want.push(next);
            logits = decode_step(&m, next, &mut cache).unwrap();
        }
        assert_eq!(second[0].generated, want, "prefix reuse changed the decode");
    }

    #[test]
    fn preemption_under_tiny_pool_still_completes_correctly() {
        let m = tiny_model(Arch::GeluNeoX);
        // Pool fits ~1.5 sequences: joins are budget-refused or preempted,
        // but everything must finish with oracle-identical text.
        let prompts: Vec<(Vec<u32>, usize)> =
            vec![(vec![1, 2, 3, 4], 4), (vec![5, 6, 7], 4), (vec![8, 9], 4)];
        let mut oracle_texts = Vec::new();
        for (p, n) in &prompts {
            let mut cache = KvCache::new(&m.cfg);
            let mut logits = Vec::new();
            for &t in p {
                logits = decode_step(&m, t, &mut cache).unwrap();
            }
            let mut gen = Vec::new();
            for _ in 0..*n {
                let next = crate::eval::argmax(&logits) as u32;
                gen.push(next);
                logits = decode_step(&m, next, &mut cache).unwrap();
            }
            oracle_texts.push(gen);
        }
        let mut paged = PagedDecodeBatch::new(
            &m.cfg,
            PagedBatchConfig { block_size: 2, n_blocks: 6, slots: 3 },
        );
        let mut joined: Vec<Option<u64>> = prompts.iter().map(|_| None).collect();
        let mut finished: Vec<FinishedSeq> = Vec::new();
        let mut guard = 0;
        loop {
            for (i, (p, n)) in prompts.iter().enumerate() {
                if joined[i].is_none() {
                    joined[i] = paged.try_join(p.clone(), *n);
                }
            }
            if !paged.has_work() && joined.iter().all(|j| j.is_some()) {
                break;
            }
            paged.step(&m);
            finished.extend(paged.retire_finished());
            guard += 1;
            assert!(guard < 512, "tiny-pool schedule failed to converge");
        }
        finished.extend(paged.retire_finished());
        assert_eq!(finished.len(), 3);
        for (i, (p, _)) in prompts.iter().enumerate() {
            let f = finished.iter().find(|f| f.prompt == *p).unwrap();
            assert_eq!(f.generated, oracle_texts[i], "prompt {i} text diverged");
        }
        assert!(
            paged.preemptions > 0,
            "a 6-block pool under ~11 blocks of demand must preempt"
        );
    }

    #[test]
    fn paged_chunked_multi_pass_is_bitwise_identical_to_single_rows() {
        // Kernel-level pin, paged sibling of the dense test: feeding a
        // prompt through decode_step_paged_multi in chunks of C produces
        // byte-identical per-position logits to one token per pass.
        let m = tiny_model(Arch::GeluNeoX);
        let prompt: Vec<u32> = (0..20u32).map(|i| (i * 7 + 3) % 60).collect();
        let mut oracle_pool = BlockPool::new(&m.cfg, 4, 32);
        let mut oracle_cache = PagedKvCache::new();
        let mut oracle_logits: Vec<Vec<f32>> = Vec::new();
        for &t in &prompt {
            let rows = [(0usize, t)];
            let mut refs = vec![&mut oracle_cache];
            let l =
                decode_step_paged_multi(&m, &rows, &mut oracle_pool, &mut refs, None).unwrap();
            oracle_logits.push(l.row(0).to_vec());
        }
        for chunk in [1usize, 4, 7, 16, 256] {
            let mut pool = BlockPool::new(&m.cfg, 4, 32);
            let mut cache = PagedKvCache::new();
            let mut got: Vec<Vec<f32>> = Vec::new();
            let mut fed = 0;
            while fed < prompt.len() {
                let c = chunk.min(prompt.len() - fed);
                let rows: Vec<(usize, u32)> =
                    prompt[fed..fed + c].iter().map(|&t| (0usize, t)).collect();
                let mut refs = vec![&mut cache];
                let l = decode_step_paged_multi(&m, &rows, &mut pool, &mut refs, None).unwrap();
                for r in 0..c {
                    got.push(l.row(r).to_vec());
                }
                fed += c;
            }
            assert_eq!(got, oracle_logits, "chunk {chunk}: paged logits must be bitwise equal");
            cache.release(&mut pool);
        }
    }

    #[test]
    fn paged_chunked_prefill_matches_monolithic_and_publishes_same_trie() {
        // End-to-end pin: a PagedDecodeBatch running chunked prefill emits
        // byte-identical token streams, publishes the same number of
        // prefix-trie blocks, and serves the same trie hits to a
        // follow-up shared-prefix join as the chunk=1 baseline — with a
        // speculative row sharing the batch. Chunk 256 ≥ every prompt.
        let m = tiny_model(Arch::SwiGlu);
        let prefix: Vec<u32> = (0..12u32).map(|i| (i * 3 + 1) % 60).collect();
        let run = |chunk: usize| -> (Vec<(Vec<u32>, Vec<u32>)>, usize, u64, Vec<u32>) {
            let mut paged = PagedDecodeBatch::new(
                &m.cfg,
                PagedBatchConfig { block_size: 4, n_blocks: 0, slots: 3 },
            );
            paged.set_prefill_chunk(chunk);
            let mut long = prefix.clone();
            long.extend_from_slice(&[7, 8]);
            paged.try_join(long, 4).unwrap();
            let mut spec = SeqSpec::greedy(vec![9, 1, 2, 3, 4], 6);
            spec.spec_k = Some(3);
            paged.try_join_spec(spec).unwrap();
            paged.try_join(vec![40, 3, 3], 4).unwrap();
            let mut out = Vec::new();
            let mut guard = 0;
            while paged.has_work() {
                paged.step(&m);
                out.extend(
                    paged.retire_finished().into_iter().map(|f| (f.prompt, f.generated)),
                );
                guard += 1;
                assert!(guard < 128, "chunk {chunk}: did not converge");
            }
            out.extend(paged.retire_finished().into_iter().map(|f| (f.prompt, f.generated)));
            out.sort();
            let published = paged.trie.blocks_held();
            // Follow-up join sharing the 12-token prefix: its trie hits
            // and its text certify the published blocks are the same KV.
            let mut tail = prefix.clone();
            tail.extend_from_slice(&[50, 51]);
            paged.try_join(tail, 3).unwrap();
            let mut follow = Vec::new();
            let mut guard = 0;
            while paged.has_work() {
                paged.step(&m);
                follow.extend(paged.retire_finished().into_iter().map(|f| f.generated));
                guard += 1;
                assert!(guard < 64, "chunk {chunk}: follow-up did not converge");
            }
            follow.extend(paged.retire_finished().into_iter().map(|f| f.generated));
            assert_eq!(follow.len(), 1);
            (out, published, paged.prefix_hit_tokens, follow.remove(0))
        };
        let (base_out, base_published, base_hits, base_follow) = run(1);
        assert_eq!(base_out.len(), 3);
        assert!(base_published > 0, "completed prefills must publish blocks");
        assert_eq!(base_hits, 12, "3 full blocks of 4 must be reused by the follow-up");
        for chunk in [4usize, 16, 256] {
            let (out, published, hits, follow) = run(chunk);
            assert_eq!(out, base_out, "chunk {chunk}: token streams diverged");
            assert_eq!(published, base_published, "chunk {chunk}: trie publication diverged");
            assert_eq!(hits, base_hits, "chunk {chunk}: prefix reuse diverged");
            assert_eq!(follow, base_follow, "chunk {chunk}: reused-prefix decode diverged");
        }
    }

    #[test]
    fn chunked_prefill_under_tiny_pool_degrades_and_stays_correct() {
        // Pool pressure must shrink chunks / preempt without changing any
        // text: same oracle pin as the preemption test, chunk 4.
        let m = tiny_model(Arch::GeluNeoX);
        let prompts: Vec<(Vec<u32>, usize)> =
            vec![(vec![1, 2, 3, 4], 4), (vec![5, 6, 7], 4), (vec![8, 9], 4)];
        let mut oracle_texts = Vec::new();
        for (p, n) in &prompts {
            let mut cache = KvCache::new(&m.cfg);
            let mut logits = Vec::new();
            for &t in p {
                logits = decode_step(&m, t, &mut cache).unwrap();
            }
            let mut gen = Vec::new();
            for _ in 0..*n {
                let next = crate::eval::argmax(&logits) as u32;
                gen.push(next);
                logits = decode_step(&m, next, &mut cache).unwrap();
            }
            oracle_texts.push(gen);
        }
        let mut paged = PagedDecodeBatch::new(
            &m.cfg,
            PagedBatchConfig { block_size: 2, n_blocks: 6, slots: 3 },
        );
        paged.set_prefill_chunk(4);
        let mut joined: Vec<Option<u64>> = prompts.iter().map(|_| None).collect();
        let mut finished: Vec<FinishedSeq> = Vec::new();
        let mut guard = 0;
        loop {
            for (i, (p, n)) in prompts.iter().enumerate() {
                if joined[i].is_none() {
                    joined[i] = paged.try_join(p.clone(), *n);
                }
            }
            if !paged.has_work() && joined.iter().all(|j| j.is_some()) {
                break;
            }
            paged.step(&m);
            finished.extend(paged.retire_finished());
            guard += 1;
            assert!(guard < 512, "tiny-pool chunked schedule failed to converge");
        }
        finished.extend(paged.retire_finished());
        assert_eq!(finished.len(), 3);
        for (i, (p, _)) in prompts.iter().enumerate() {
            let f = finished.iter().find(|f| f.prompt == *p).unwrap();
            assert_eq!(f.generated, oracle_texts[i], "prompt {i} text diverged under pressure");
        }
    }

    #[test]
    fn empty_prompt_and_zero_gen_are_degenerate_but_safe() {
        let m = tiny_model(Arch::SwiGlu);
        let mut paged = PagedDecodeBatch::new(&m.cfg, PagedBatchConfig::default());
        paged.try_join(vec![], 4).unwrap();
        paged.try_join(vec![1, 2], 0).unwrap();
        let long: Vec<u32> = (0..m.cfg.max_seq as u32 + 8).map(|i| i % 60).collect();
        paged.try_join(long, 2).unwrap();
        let mut guard = 0;
        while paged.has_work() {
            paged.step(&m);
            paged.retire_finished();
            guard += 1;
            assert!(guard < 2 * m.cfg.max_seq + 16, "did not converge");
        }
        paged.retire_finished();
        assert_eq!(paged.active(), 0);
    }
}
