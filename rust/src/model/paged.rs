//! Paged decode path: batched decoding over the block-pool KV cache
//! (`crate::kvcache`), with shared-prefix reuse, memory-aware admission,
//! and preemption under pool pressure.
//!
//! [`decode_step_paged`] computes, row for row, exactly what
//! [`super::forward::decode_step_batch`] computes over dense caches — the
//! only change is KV row *addressing* (block chains into the shared pool,
//! attended by [`crate::tensor::attention_over_paged`]), so its logits are
//! bit-for-bit identical to the contiguous path and the dense cache stays
//! the test oracle (DESIGN.md §2b).
//!
//! [`PagedDecodeBatch`] is the paged sibling of [`super::DecodeBatch`]:
//! same join/step/retire schedule over a virtual token stream
//! `prompt ++ generated`, plus
//!
//! * **prefix reuse** — joins adopt the longest full-block prompt prefix
//!   from the [`PrefixTrie`] and skip prefill for those tokens entirely;
//!   completed prefills publish their full prompt blocks back to the trie;
//! * **memory-aware admission** — a join is admitted against the pool's
//!   free-block budget (after trying trie eviction), not just a slot count;
//! * **preemption** — when an append finds the pool exhausted mid-flight,
//!   trie eviction is tried first, then the youngest other live sequence
//!   releases its blocks and requeues (its refeed re-runs prefill, usually
//!   hitting the trie). Greedy decoding is deterministic, so preemption
//!   never changes a sequence's text.

use std::collections::VecDeque;

use super::config::ModelConfig;
use super::forward::{decode_step_body, BlockOps, FinishedSeq, SeqSpec, AMBIENT_BUDGET};
use super::ops;
use crate::kvcache::{BlockPool, CacheError, PagedKvCache, PrefixTrie};
use crate::tensor::{attention_over_paged, Mat};

/// One batched decode step over paged caches: row `r` of `tokens`/`seqs`
/// appends at its own position `seqs[r].len()`. Returns logits `[N, vocab]`
/// or a typed [`CacheError`] (positional capacity, or pool exhaustion from
/// the up-front block allocation) *before* any KV row is written.
pub fn decode_step_paged<B: BlockOps>(
    b: &B,
    tokens: &[u32],
    pool: &mut BlockPool,
    seqs: &mut [&mut PagedKvCache],
) -> Result<Mat, CacheError> {
    decode_step_paged_inner(b, tokens, pool, seqs, None)
}

/// [`decode_step_paged`] with a per-row compute budget (see
/// [`super::forward::decode_step_batch_budgeted`] — the budget threading is
/// identical on both cache layouts by construction).
pub fn decode_step_paged_budgeted<B: BlockOps>(
    b: &B,
    tokens: &[u32],
    pool: &mut BlockPool,
    seqs: &mut [&mut PagedKvCache],
    rates: &[f64],
) -> Result<Mat, CacheError> {
    assert_eq!(tokens.len(), rates.len(), "decode_step_paged_budgeted arity");
    decode_step_paged_inner(b, tokens, pool, seqs, Some(rates))
}

fn decode_step_paged_inner<B: BlockOps>(
    b: &B,
    tokens: &[u32],
    pool: &mut BlockPool,
    seqs: &mut [&mut PagedKvCache],
    rates: Option<&[f64]>,
) -> Result<Mat, CacheError> {
    assert_eq!(tokens.len(), seqs.len(), "decode_step_paged arity");
    let cfg = b.config().clone();
    let positions: Vec<usize> = seqs.iter().map(|s| s.len()).collect();
    for (r, &pos) in positions.iter().enumerate() {
        if pos >= cfg.max_seq {
            return Err(CacheError::CacheFull { seq: r, pos, capacity: cfg.max_seq });
        }
    }
    // Make every append target writable up front (block alloc + COW), so a
    // pool failure surfaces before any state is mutated. Idempotent for
    // callers (the batcher) that already prepared.
    for (r, s) in seqs.iter_mut().enumerate() {
        s.prepare_append(pool).map_err(|e| e.with_seq(r))?;
    }

    let bs = pool.block_size();
    let n_heads = cfg.n_heads;
    // Same per-layer body as the dense path — only the KV addressing in
    // this closure differs, which is what makes the paged logits
    // bit-for-bit identical to the contiguous oracle by construction.
    let logits = decode_step_body(b, tokens, &positions, rates, |layer, r, q, k, v| {
        seqs[r].write_kv(pool, layer, k, v);
        attention_over_paged(
            q,
            pool.layer_k(layer),
            pool.layer_v(layer),
            seqs[r].chain(),
            bs,
            positions[r] + 1,
            n_heads,
        )
    });
    for s in seqs.iter_mut() {
        s.advance();
    }
    Ok(logits)
}

/// Sizing of a [`PagedDecodeBatch`].
#[derive(Clone, Copy, Debug)]
pub struct PagedBatchConfig {
    /// Tokens per KV block.
    pub block_size: usize,
    /// Total pool blocks; `0` → dense-equivalent memory
    /// (`slots × ⌈max_seq / block_size⌉`).
    pub n_blocks: usize,
    /// Maximum in-flight sequences per engine pass.
    pub slots: usize,
}

impl Default for PagedBatchConfig {
    fn default() -> Self {
        Self { block_size: 16, n_blocks: 0, slots: 8 }
    }
}

/// State of one in-flight sequence. `fed` indexes the virtual token stream
/// `prompt ++ generated`, so a preempted sequence simply resets `fed` and
/// re-runs prefill over everything it had already committed to.
struct PagedSeqState {
    id: u64,
    prompt: Vec<u32>,
    fed: usize,
    n_gen: usize,
    sampling: ops::Sampling,
    rng: crate::util::rng::Xoshiro256,
    budget: Option<f64>,
    generated: Vec<u32>,
    last_logits: Vec<f32>,
    cache: PagedKvCache,
    done: bool,
    /// Prompt's full blocks have been published to the trie.
    prompt_in_trie: bool,
}

impl PagedSeqState {
    fn stream_len(&self) -> usize {
        self.prompt.len() + self.generated.len()
    }

    fn stream_tok(&self, i: usize) -> u32 {
        if i < self.prompt.len() {
            self.prompt[i]
        } else {
            self.generated[i - self.prompt.len()]
        }
    }
}

/// Iteration-level batched greedy decoder over a shared [`BlockPool`] —
/// the paged replacement for [`super::DecodeBatch`] (see module docs).
pub struct PagedDecodeBatch {
    cfg: ModelConfig,
    pool: BlockPool,
    trie: PrefixTrie,
    slots: Vec<Option<PagedSeqState>>,
    /// Preempted sequences awaiting re-admission (front = oldest).
    preempted: VecDeque<PagedSeqState>,
    /// Tokens generated since the last [`PagedDecodeBatch::drain_emitted`].
    emitted: Vec<(u64, u32)>,
    /// Sequences cancelled while preempted (no slot to retire from).
    finished_aside: Vec<FinishedSeq>,
    next_id: u64,
    /// Tokens fed across all steps (batch-occupancy accounting).
    pub tokens_processed: u64,
    /// Engine passes executed.
    pub steps: u64,
    /// Prompt tokens whose prefill was skipped via trie hits.
    pub prefix_hit_tokens: u64,
    /// Sequences preempted (blocks released, requeued) under pool pressure.
    pub preemptions: u64,
}

impl PagedDecodeBatch {
    pub fn new(cfg: &ModelConfig, pc: PagedBatchConfig) -> Self {
        let slots = pc.slots.max(1);
        let block_size = pc.block_size.max(1);
        let dense_equiv = slots * cfg.max_seq.div_ceil(block_size);
        let n_blocks = if pc.n_blocks == 0 { dense_equiv } else { pc.n_blocks };
        Self {
            cfg: cfg.clone(),
            pool: BlockPool::new(cfg, block_size, n_blocks),
            trie: PrefixTrie::new(),
            slots: (0..slots).map(|_| None).collect(),
            preempted: VecDeque::new(),
            emitted: Vec::new(),
            finished_aside: Vec::new(),
            next_id: 0,
            tokens_processed: 0,
            steps: 0,
            prefix_hit_tokens: 0,
            preemptions: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Sequences currently admitted, awaiting re-admission, or finished
    /// aside (all still owe their caller a result).
    pub fn active(&self) -> usize {
        self.slots.iter().flatten().count() + self.preempted.len() + self.finished_aside.len()
    }

    pub fn has_work(&self) -> bool {
        self.slots.iter().flatten().any(|s| !s.done) || !self.preempted.is_empty()
    }

    pub fn pool(&self) -> &BlockPool {
        &self.pool
    }

    /// Pool snapshot for the serving metrics:
    /// `(blocks_in_use, blocks_peak, prefix_hit_tokens, preemptions)`.
    pub fn kv_stats(&self) -> (usize, usize, u64, u64) {
        (
            self.pool.blocks_in_use(),
            self.pool.blocks_peak(),
            self.prefix_hit_tokens,
            self.preemptions,
        )
    }

    fn live_count(&self) -> usize {
        self.slots.iter().flatten().filter(|s| !s.done).count()
    }

    /// Admit `st` against the free-block budget: adopt the longest shared
    /// prompt prefix from the trie, then require the sequence's whole
    /// remaining run to fit in free blocks (after trying trie eviction).
    /// `force` overrides the budget when nothing else is in flight, so one
    /// sequence always makes progress.
    fn admit(&mut self, st: &mut PagedSeqState, force: bool) -> bool {
        let bs = self.pool.block_size();
        // At least one stream token must remain to feed (its logits seed
        // generation), and only prompt tokens live in the trie. Sequences
        // carrying a per-request budget override bypass the trie entirely:
        // KV computed at one compute budget must never seed decoding at
        // another.
        let reusable = st.stream_len().saturating_sub(1).min(st.prompt.len());
        let chain = if st.budget.is_some() {
            Vec::new()
        } else {
            self.trie.lookup(&st.prompt, reusable / bs, &mut self.pool)
        };
        let matched = chain.len() * bs;
        // Optimistic (vLLM-style) budget: the stream already committed plus
        // one generated token must fit *now*; later decode growth is served
        // lazily and handled by eviction/preemption when the pool runs dry.
        let total = (st.stream_len() + 1).min(self.cfg.max_seq);
        let needed = self.pool.blocks_for(total).saturating_sub(chain.len());
        if self.pool.free_blocks() < needed {
            let short = needed - self.pool.free_blocks();
            self.trie.evict(&mut self.pool, short);
        }
        if self.pool.free_blocks() < needed && !force {
            for &b in &chain {
                self.pool.release(b);
            }
            return false;
        }
        self.prefix_hit_tokens += matched as u64;
        st.cache = PagedKvCache::from_shared_prefix(chain, matched, bs);
        st.fed = matched;
        true
    }

    /// Admit a sequence; `None` when every slot is occupied **or** the
    /// free-block budget refuses the join (retry after steps retire or
    /// preemption frees blocks).
    pub fn try_join(&mut self, prompt: Vec<u32>, n_gen: usize) -> Option<u64> {
        self.try_join_spec(SeqSpec::greedy(prompt, n_gen))
    }

    /// Admit a sequence with explicit sampling params and budget override.
    pub fn try_join_spec(&mut self, spec: SeqSpec) -> Option<u64> {
        let slot_idx = self.slots.iter().position(|s| s.is_none())?;
        let done = spec.prompt.is_empty();
        let mut st = PagedSeqState {
            id: 0,
            prompt: spec.prompt,
            fed: 0,
            n_gen: spec.max_new,
            rng: crate::util::rng::Xoshiro256::new(spec.sampling.seed),
            sampling: spec.sampling,
            budget: spec.budget,
            generated: Vec::new(),
            last_logits: Vec::new(),
            cache: PagedKvCache::new(),
            done,
            prompt_in_trie: false,
        };
        if !done {
            let force = self.live_count() == 0 && self.preempted.is_empty();
            if !self.admit(&mut st, force) {
                return None;
            }
        }
        st.id = self.next_id;
        self.next_id += 1;
        let id = st.id;
        self.slots[slot_idx] = Some(st);
        Some(id)
    }

    fn finish(pool: &mut BlockPool, s: &mut PagedSeqState) {
        s.done = true;
        s.cache.release(pool);
    }

    /// Mark a sequence finished where it stands (client cancel), releasing
    /// its blocks; its partial result is returned by the next retire. A
    /// preempted sequence is retired from the side queue. Returns false
    /// for unknown ids.
    pub fn cancel(&mut self, id: u64) -> bool {
        for s in self.slots.iter_mut().flatten() {
            if s.id == id {
                if !s.done {
                    Self::finish(&mut self.pool, s);
                }
                return true;
            }
        }
        if let Some(p) = self.preempted.iter().position(|s| s.id == id) {
            // Blocks were already released at preemption time.
            let s = self.preempted.remove(p).expect("checked position");
            self.finished_aside.push(FinishedSeq {
                id: s.id,
                prompt: s.prompt,
                generated: s.generated,
            });
            return true;
        }
        false
    }

    /// Tokens generated since the last drain, in generation order.
    pub fn drain_emitted(&mut self) -> Vec<(u64, u32)> {
        std::mem::take(&mut self.emitted)
    }

    /// Put drained-but-unconsumed tokens back at the front of the stream
    /// (a session on the shared batch returns other sessions' deltas).
    pub fn restore_emitted(&mut self, mut items: Vec<(u64, u32)>) {
        items.extend(std::mem::take(&mut self.emitted));
        self.emitted = items;
    }

    /// Drop every shared-prefix entry. Called on shared-budget retunes:
    /// trie blocks hold KV computed at the old budget, which must not seed
    /// prefills at the new one. In-flight sequences are barred from
    /// publishing too — a prefill straddling the retune holds
    /// mixed-budget KV in its private chain, which must stay private.
    pub fn flush_prefix_cache(&mut self) {
        self.trie.clear(&mut self.pool);
        for s in self.slots.iter_mut().flatten() {
            s.prompt_in_trie = true;
        }
    }

    /// Youngest live sequence other than slot `except` (preemption victim).
    fn youngest_other_live(&self, except: usize) -> Option<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(i, s)| *i != except && s.as_ref().map(|s| !s.done).unwrap_or(false))
            .max_by_key(|(_, s)| s.as_ref().map(|s| s.id).unwrap_or(0))
            .map(|(i, _)| i)
    }

    /// One engine pass; returns how many sequences advanced. Handles
    /// re-admission of preempted sequences, per-sequence block preparation
    /// with eviction/preemption under pool pressure, the batched paged
    /// forward, and trie publication of completed prefills.
    pub fn step<B: BlockOps>(&mut self, b: &B) -> usize {
        let max_seq = self.cfg.max_seq;
        let bs = self.pool.block_size();

        // 1. Re-admit preempted sequences into free slots, oldest first.
        while let Some(free_idx) = self.slots.iter().position(|s| s.is_none()) {
            let Some(mut st) = self.preempted.pop_front() else { break };
            let force = self.live_count() == 0;
            if self.admit(&mut st, force) {
                self.slots[free_idx] = Some(st);
            } else {
                self.preempted.push_front(st);
                break;
            }
        }

        // 2. Token selection over the virtual stream (same schedule as the
        // dense DecodeBatch; `fed` resets on preemption).
        let mut stepping: Vec<usize> = Vec::new();
        let mut tokens: Vec<u32> = Vec::new();
        for idx in 0..self.slots.len() {
            let Some(s) = self.slots[idx].as_mut() else { continue };
            if s.done {
                continue;
            }
            if s.cache.len() >= max_seq {
                // Over-long prompt: truncate prefill rather than overflow.
                Self::finish(&mut self.pool, s);
                continue;
            }
            let tok = if s.fed < s.stream_len() {
                let t = s.stream_tok(s.fed);
                s.fed += 1;
                t
            } else if s.generated.len() >= s.n_gen {
                Self::finish(&mut self.pool, s);
                continue;
            } else if s.cache.len() + 1 >= max_seq {
                Self::finish(&mut self.pool, s);
                continue;
            } else {
                let next = ops::sample_token(&s.last_logits, &s.sampling, &mut s.rng);
                s.generated.push(next);
                self.emitted.push((s.id, next));
                if s.generated.len() >= s.n_gen {
                    // Final token: recorded, needs no engine pass.
                    Self::finish(&mut self.pool, s);
                    continue;
                }
                s.fed += 1;
                next
            };
            stepping.push(idx);
            tokens.push(tok);
        }

        // 3. Prepare every append (alloc/COW). On exhaustion: evict
        // trie-only blocks, else preempt the youngest other live sequence;
        // a sequence the pool cannot hold even alone is truncated.
        let mut i = 0;
        while i < stepping.len() {
            let idx = stepping[i];
            let res = self.slots[idx]
                .as_mut()
                .expect("stepping slot occupied")
                .cache
                .prepare_append(&mut self.pool);
            match res {
                Ok(()) => i += 1,
                Err(_) => {
                    if self.trie.evict(&mut self.pool, 1) > 0 {
                        continue; // retry this sequence
                    }
                    match self.youngest_other_live(idx) {
                        Some(v) => {
                            let mut st = self.slots[v].take().expect("victim occupied");
                            st.cache.release(&mut self.pool);
                            st.fed = 0;
                            st.prompt_in_trie = false;
                            self.preemptions += 1;
                            self.preempted.push_back(st);
                            if let Some(p) = stepping.iter().position(|&x| x == v) {
                                if p < i {
                                    i -= 1;
                                }
                                stepping.remove(p);
                                tokens.remove(p);
                            }
                        }
                        None => {
                            let s = self.slots[idx].as_mut().expect("stepping slot occupied");
                            Self::finish(&mut self.pool, s);
                            stepping.remove(i);
                            tokens.remove(i);
                        }
                    }
                }
            }
        }

        // 4. Batched paged forward. CacheErrors are unreachable after the
        // guards above, but the contract stands: the offending sequence
        // retires; the pass retries with the rest.
        let logits = loop {
            if stepping.is_empty() {
                return 0;
            }
            let res = {
                // Per-row budgets only when some sequence carries an
                // override (all-ambient batches keep the legacy call).
                let rates: Option<Vec<f64>> = stepping
                    .iter()
                    .any(|&i| self.slots[i].as_ref().is_some_and(|s| s.budget.is_some()))
                    .then(|| {
                        stepping
                            .iter()
                            .map(|&i| {
                                self.slots[i]
                                    .as_ref()
                                    .and_then(|s| s.budget)
                                    .unwrap_or(AMBIENT_BUDGET)
                            })
                            .collect()
                    });
                let mut seq_refs: Vec<&mut PagedKvCache> = Vec::with_capacity(stepping.len());
                let mut want = stepping.iter().peekable();
                for (idx, slot) in self.slots.iter_mut().enumerate() {
                    if want.peek() == Some(&&idx) {
                        want.next();
                        seq_refs.push(&mut slot.as_mut().expect("stepping slot occupied").cache);
                    }
                }
                decode_step_paged_inner(b, &tokens, &mut self.pool, &mut seq_refs, rates.as_deref())
            };
            match res {
                Ok(l) => break l,
                Err(e) => {
                    let p = e.seq().min(stepping.len() - 1);
                    let idx = stepping.remove(p);
                    tokens.remove(p);
                    let s = self.slots[idx].as_mut().expect("stepping slot occupied");
                    Self::finish(&mut self.pool, s);
                }
            }
        };

        // 5. Record logits; publish completed prefills' full prompt blocks.
        for (r, &idx) in stepping.iter().enumerate() {
            let s = self.slots[idx].as_mut().expect("stepping slot occupied");
            s.last_logits = logits.row(r).to_vec();
            if s.budget.is_some() {
                // Budget-overridden KV stays private (see `admit`).
                s.prompt_in_trie = true;
            }
            if !s.prompt_in_trie && s.cache.len() >= s.prompt.len() {
                let n_full = s.prompt.len() / bs;
                if n_full > 0 {
                    self.trie.insert(&s.prompt, &s.cache.chain()[..n_full], &mut self.pool);
                }
                s.prompt_in_trie = true;
            }
        }
        let n = stepping.len();
        self.steps += 1;
        self.tokens_processed += n as u64;
        n
    }

    /// Remove finished sequences, freeing their slots (their blocks were
    /// already released at finish time).
    pub fn retire_finished(&mut self) -> Vec<FinishedSeq> {
        self.retire_finished_owned(|_| true)
    }

    /// Like [`PagedDecodeBatch::retire_finished`], but only for sequences
    /// whose id satisfies `owned`. An engine-persistent batch can host
    /// sequences admitted by several sessions; each session retires only
    /// its own, leaving the rest in their slots for their owners.
    pub fn retire_finished_owned(&mut self, owned: impl Fn(u64) -> bool) -> Vec<FinishedSeq> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.finished_aside.len() {
            if owned(self.finished_aside[i].id) {
                out.push(self.finished_aside.swap_remove(i));
            } else {
                i += 1;
            }
        }
        for slot in &mut self.slots {
            if slot.as_ref().map(|s| s.done && owned(s.id)).unwrap_or(false) {
                let s = slot.take().expect("checked above");
                out.push(FinishedSeq { id: s.id, prompt: s.prompt, generated: s.generated });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::Arch;
    use crate::model::forward::{decode_step, decode_step_batch, KvCache, Model};
    use crate::model::weights::ModelWeights;

    fn tiny_cfg(arch: Arch) -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            arch,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_hidden: 32,
            vocab: 64,
            max_seq: 32,
            rope_theta: 10_000.0,
            norm_eps: 1e-5,
        }
    }

    fn tiny_model(arch: Arch) -> Model {
        let cfg = tiny_cfg(arch);
        let w = ModelWeights::random_init(&cfg, 11);
        Model::new(cfg, w).unwrap()
    }

    #[test]
    fn paged_step_bitwise_matches_dense_batch() {
        for arch in [Arch::SwiGlu, Arch::GeluNeoX] {
            let m = tiny_model(arch);
            for &bs in &[1usize, 7, 16] {
                let mut pool = BlockPool::new(&m.cfg, bs, 64);
                let streams: Vec<Vec<u32>> =
                    vec![vec![1, 5, 9, 30, 2, 17], vec![8, 8, 1, 0, 63, 2]];
                let mut dense: Vec<KvCache> =
                    streams.iter().map(|_| KvCache::new(&m.cfg)).collect();
                let mut paged: Vec<PagedKvCache> =
                    streams.iter().map(|_| PagedKvCache::new()).collect();
                for t in 0..streams[0].len() {
                    let toks: Vec<u32> = streams.iter().map(|s| s[t]).collect();
                    let mut drefs: Vec<&mut KvCache> = dense.iter_mut().collect();
                    let want = decode_step_batch(&m, &toks, &mut drefs).unwrap();
                    let mut prefs: Vec<&mut PagedKvCache> = paged.iter_mut().collect();
                    let got = decode_step_paged(&m, &toks, &mut pool, &mut prefs).unwrap();
                    assert_eq!(got.data, want.data, "arch {arch:?} bs {bs} step {t}");
                }
                for mut p in paged {
                    p.release(&mut pool);
                }
                assert_eq!(pool.free_blocks(), 64);
            }
        }
    }

    #[test]
    fn paged_batch_reproduces_dense_batch_texts() {
        let m = tiny_model(Arch::SwiGlu);
        let prompts: Vec<(Vec<u32>, usize)> =
            vec![(vec![1, 2, 3], 4), (vec![4, 5], 3), (vec![9, 9, 9, 9], 2)];
        // Dense oracle.
        let mut dense = super::super::forward::DecodeBatch::new(&m.cfg, 3);
        for (p, n) in &prompts {
            dense.try_join(p.clone(), *n).unwrap();
        }
        let mut want = Vec::new();
        while dense.has_work() {
            dense.step(&m);
            want.extend(dense.retire_finished());
        }
        want.extend(dense.retire_finished());
        want.sort_by_key(|f| f.prompt.clone());
        // Paged, small blocks.
        let mut paged = PagedDecodeBatch::new(
            &m.cfg,
            PagedBatchConfig { block_size: 2, n_blocks: 0, slots: 3 },
        );
        for (p, n) in &prompts {
            paged.try_join(p.clone(), *n).unwrap();
        }
        let mut got = Vec::new();
        let mut guard = 0;
        while paged.has_work() {
            paged.step(&m);
            got.extend(paged.retire_finished());
            guard += 1;
            assert!(guard < 128, "paged batch failed to converge");
        }
        got.extend(paged.retire_finished());
        got.sort_by_key(|f| f.prompt.clone());
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.prompt, w.prompt);
            assert_eq!(g.generated, w.generated, "paged text diverged from dense oracle");
        }
        // All blocks returned (trie may retain prompt blocks).
        assert_eq!(
            paged.pool.blocks_in_use(),
            paged.trie.blocks_held(),
            "retired sequences must only leave trie-held blocks"
        );
    }

    #[test]
    fn shared_prefix_skips_prefill_and_matches_solo_decode() {
        let m = tiny_model(Arch::SwiGlu);
        let prefix: Vec<u32> = (0..8).map(|i| (i * 3 + 1) % 60).collect();
        let mk = |tail: &[u32]| {
            let mut p = prefix.clone();
            p.extend_from_slice(tail);
            p
        };
        let mut paged = PagedDecodeBatch::new(
            &m.cfg,
            PagedBatchConfig { block_size: 4, n_blocks: 0, slots: 2 },
        );
        // First request warms the trie.
        paged.try_join(mk(&[7]), 3).unwrap();
        while paged.has_work() {
            paged.step(&m);
        }
        let first = paged.retire_finished();
        assert_eq!(first.len(), 1);
        assert_eq!(paged.prefix_hit_tokens, 0, "cold trie cannot hit");
        assert!(paged.trie.blocks_held() > 0, "completed prefill must publish blocks");

        // Second request with the same 8-token prefix: 2 full blocks reused.
        paged.try_join(mk(&[50, 51]), 3).unwrap();
        while paged.has_work() {
            paged.step(&m);
        }
        let second = paged.retire_finished();
        assert_eq!(paged.prefix_hit_tokens, 8, "2 full blocks of 4 must be reused");
        // Reused-prefix decode must equal an isolated sequential decode.
        let mut cache = KvCache::new(&m.cfg);
        let mut logits = Vec::new();
        for &t in &mk(&[50, 51]) {
            logits = decode_step(&m, t, &mut cache).unwrap();
        }
        let mut want = Vec::new();
        for _ in 0..3 {
            let next = crate::eval::argmax(&logits) as u32;
            want.push(next);
            logits = decode_step(&m, next, &mut cache).unwrap();
        }
        assert_eq!(second[0].generated, want, "prefix reuse changed the decode");
    }

    #[test]
    fn preemption_under_tiny_pool_still_completes_correctly() {
        let m = tiny_model(Arch::GeluNeoX);
        // Pool fits ~1.5 sequences: joins are budget-refused or preempted,
        // but everything must finish with oracle-identical text.
        let prompts: Vec<(Vec<u32>, usize)> =
            vec![(vec![1, 2, 3, 4], 4), (vec![5, 6, 7], 4), (vec![8, 9], 4)];
        let mut oracle_texts = Vec::new();
        for (p, n) in &prompts {
            let mut cache = KvCache::new(&m.cfg);
            let mut logits = Vec::new();
            for &t in p {
                logits = decode_step(&m, t, &mut cache).unwrap();
            }
            let mut gen = Vec::new();
            for _ in 0..*n {
                let next = crate::eval::argmax(&logits) as u32;
                gen.push(next);
                logits = decode_step(&m, next, &mut cache).unwrap();
            }
            oracle_texts.push(gen);
        }
        let mut paged = PagedDecodeBatch::new(
            &m.cfg,
            PagedBatchConfig { block_size: 2, n_blocks: 6, slots: 3 },
        );
        let mut joined: Vec<Option<u64>> = prompts.iter().map(|_| None).collect();
        let mut finished: Vec<FinishedSeq> = Vec::new();
        let mut guard = 0;
        loop {
            for (i, (p, n)) in prompts.iter().enumerate() {
                if joined[i].is_none() {
                    joined[i] = paged.try_join(p.clone(), *n);
                }
            }
            if !paged.has_work() && joined.iter().all(|j| j.is_some()) {
                break;
            }
            paged.step(&m);
            finished.extend(paged.retire_finished());
            guard += 1;
            assert!(guard < 512, "tiny-pool schedule failed to converge");
        }
        finished.extend(paged.retire_finished());
        assert_eq!(finished.len(), 3);
        for (i, (p, _)) in prompts.iter().enumerate() {
            let f = finished.iter().find(|f| f.prompt == *p).unwrap();
            assert_eq!(f.generated, oracle_texts[i], "prompt {i} text diverged");
        }
        assert!(
            paged.preemptions > 0,
            "a 6-block pool under ~11 blocks of demand must preempt"
        );
    }

    #[test]
    fn empty_prompt_and_zero_gen_are_degenerate_but_safe() {
        let m = tiny_model(Arch::SwiGlu);
        let mut paged = PagedDecodeBatch::new(&m.cfg, PagedBatchConfig::default());
        paged.try_join(vec![], 4).unwrap();
        paged.try_join(vec![1, 2], 0).unwrap();
        let long: Vec<u32> = (0..m.cfg.max_seq as u32 + 8).map(|i| i % 60).collect();
        paged.try_join(long, 2).unwrap();
        let mut guard = 0;
        while paged.has_work() {
            paged.step(&m);
            paged.retire_finished();
            guard += 1;
            assert!(guard < 2 * m.cfg.max_seq + 16, "did not converge");
        }
        paged.retire_finished();
        assert_eq!(paged.active(), 0);
    }
}
