//! Regenerates the paper's tables (Tab. 1–4) on the simulated testbed.
//!
//! Usage:
//!   cargo bench --bench paper_tables            # all tables
//!   cargo bench --bench paper_tables -- tab1    # filter
//!   cargo bench --bench paper_tables -- tab1 --full   # paper-scale eval
//!
//! Absolute numbers belong to the simulated models; the *shape* (method
//! ordering, crossovers, breakdowns) is the reproduction target — see
//! EXPERIMENTS.md for paper-vs-measured.

use rana::bench::experiments::{self, Opts};
use rana::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let mut opts = Opts::default();
    if args.get_flag("full") {
        opts.ppl_tokens = 64_000;
        opts.items = 150;
        opts.calib_fit = 4096;
    }
    if args.get_flag("fast") {
        opts.ppl_tokens = 4_000;
        opts.items = 20;
        opts.calib_fit = 512;
    }
    let mut ran = false;
    let mut run = |name: &str, f: &dyn Fn(Opts) -> anyhow::Result<()>| {
        if args.filter_matches(name) {
            ran = true;
            if let Err(e) = f(opts) {
                eprintln!("{name}: {e:#} (run `make artifacts` first?)");
            }
        }
    };
    run("tab1", &experiments::tab1);
    run("tab2", &experiments::tab2);
    run("tab3", &experiments::tab3);
    run("tab4", &experiments::tab4);
    run("ablations", &rana::bench::ablations::all);
    run("ext_model_alloc", &rana::bench::ablations::ext_model_alloc);
    run("ext_recovery", &rana::bench::ablations::ext_recovery);
    if !ran {
        eprintln!("no table matched the filter");
    }
}
