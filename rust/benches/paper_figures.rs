//! Regenerates the paper's figures (Fig. 1a, 1c, 2, 3, 4, 5) as printed
//! curves/tables/ASCII histograms. Fig. 1b lives in `--bench latency`.
//!
//! Usage: cargo bench --bench paper_figures [-- fig1a|fig1c|fig2|fig3|fig4|fig5] [--fast|--full]

use rana::bench::experiments::{self, Opts};
use rana::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let mut opts = Opts::default();
    if args.get_flag("full") {
        opts.ppl_tokens = 64_000;
        opts.items = 150;
        opts.calib_fit = 4096;
    }
    if args.get_flag("fast") {
        opts.ppl_tokens = 4_000;
        opts.items = 20;
        opts.calib_fit = 512;
    }
    let mut ran = false;
    let mut run = |name: &str, f: &dyn Fn(Opts) -> anyhow::Result<()>| {
        if args.filter_matches(name) {
            ran = true;
            if let Err(e) = f(opts) {
                eprintln!("{name}: {e:#} (run `make artifacts` first?)");
            }
        }
    };
    run("fig1a", &|o| experiments::fig1a(o, false));
    run("fig1c_fig4", &experiments::fig1c_fig4);
    run("fig2", &experiments::fig2);
    run("fig3", &experiments::fig3);
    run("fig5", &|o| experiments::fig1a(o, true));
    if !ran {
        eprintln!("no figure matched the filter");
    }
}
