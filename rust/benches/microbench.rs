//! Microbenchmarks for the performance pass (DESIGN.md §Perf): the masked
//! GEMV hot path at several densities, packed-vs-axpy GEMM across the
//! paper's shapes, the randomized SVD used at calibration time, and
//! single-token decode.
//!
//! The GEMM suite emits one JSON line per shape (`{"bench":"gemm",...}`)
//! so the packed-vs-axpy speedup lands in the bench trajectory as data,
//! not prose.
//!
//! The kernel suite pits every backend the host can run (generic scalar,
//! AVX2, NEON — `tensor::kernels`) against each other on GEMM/GEMV/softmax
//! and emits one `{"bench":"kernel_backend",...}` JSON row per (backend,
//! op), so SIMD-vs-generic speedups land in the trajectory as data.
//!
//! Usage: cargo bench --bench microbench [-- gemv|gemm|svd|decode|kernel]

use std::time::Duration;

use rana::bench::harness::bench;
use rana::model::BlockOps;
use rana::tensor::gemm::{gemm_packed, gemm_packed_with, gemm_rows_axpy};
use rana::tensor::kernels::{self, Kernel};
use rana::tensor::{masked_acc_gemv, Mat};
use rana::util::cli::Args;
use rana::util::json::Json;
use rana::util::rng::Xoshiro256;

fn gemv_suite() {
    println!("\n== masked GEMV: latency vs density (512×2048 A, the Fig.1b primitive) ==");
    let mut rng = Xoshiro256::new(1);
    let (d, o) = (512usize, 2048usize);
    let at = Mat::gaussian(d, o, 1.0, &mut rng);
    let c: Vec<f32> = (0..d).map(|_| rng.gaussian()).collect();
    let mut out = vec![0.0f32; o];
    let dense_ref = bench("dense gemv (100% density)", Duration::from_millis(300), || {
        out.fill(0.0);
        let mask = vec![true; d];
        masked_acc_gemv(&at, &mask, &c, &mut out);
        std::hint::black_box(&out);
    });
    dense_ref.print();
    for &density in &[0.75, 0.5, 0.25, 0.1] {
        let mask: Vec<bool> = (0..d).map(|i| (i as f64 / d as f64) < density).collect();
        let s = bench(
            &format!("masked gemv ({:>3.0}% density)", density * 100.0),
            Duration::from_millis(300),
            || {
                out.fill(0.0);
                masked_acc_gemv(&at, &mask, &c, &mut out);
                std::hint::black_box(&out);
            },
        );
        s.print();
        let speedup = dense_ref.mean.as_secs_f64() / s.mean.as_secs_f64();
        println!(
            "    → speedup {speedup:.2}× (ideal {:.2}×): skipping is {}linear in density",
            1.0 / density,
            if speedup > 0.8 / density { "" } else { "sub-" }
        );
    }
}

fn gemm_suite() {
    println!("\n== GEMM: packed/blocked kernel vs the seed's axpy-row loop ==");
    let mut rng = Xoshiro256::new(2);
    // The paper's shapes: sequence × (d_model → d_ff) MLP projections,
    // the fused QKV projection, a low-rank U·V product, plus square
    // references where the packed kernel's cache blocking matters most.
    let shapes: &[(&str, usize, usize, usize)] = &[
        ("seq×dmodel×dff (up-proj)", 256, 192, 512),
        ("seq×dff×dmodel (down-proj)", 256, 512, 192),
        ("seq×dmodel×3dmodel (fused qkv)", 256, 192, 576),
        ("low-rank U·V (T×r×o)", 512, 64, 512),
        ("square 256", 256, 256, 256),
        ("square 512", 512, 512, 512),
    ];
    for &(label, m, k, n) in shapes {
        let a = Mat::gaussian(m, k, 1.0, &mut rng);
        let b = Mat::gaussian(k, n, 1.0, &mut rng);
        let mut out = Mat::zeros(m, n);
        let axpy = bench(
            &format!("axpy-row gemm {m}×{k}×{n}"),
            Duration::from_millis(300),
            || {
                gemm_rows_axpy(m, k, n, &a.data, &b.data, &mut out.data, 1.0, 0.0);
                std::hint::black_box(&out);
            },
        );
        axpy.print();
        let packed = bench(
            &format!("packed gemm {m}×{k}×{n}"),
            Duration::from_millis(300),
            || {
                gemm_packed(m, k, n, &a.data, &b.data, &mut out.data, 1.0, 0.0);
                std::hint::black_box(&out);
            },
        );
        packed.print();
        let flops = 2.0 * (m * k * n) as f64;
        let axpy_gflops = flops / axpy.mean.as_secs_f64() / 1e9;
        let packed_gflops = flops / packed.mean.as_secs_f64() / 1e9;
        let speedup = axpy.mean.as_secs_f64() / packed.mean.as_secs_f64();
        println!(
            "    → {axpy_gflops:.2} → {packed_gflops:.2} GFLOP/s ({speedup:.2}× packed)"
        );
        // Machine-readable row for the bench trajectory.
        println!(
            "{}",
            Json::obj(vec![
                ("bench", Json::str("gemm")),
                ("label", Json::str(label)),
                ("m", Json::Num(m as f64)),
                ("k", Json::Num(k as f64)),
                ("n", Json::Num(n as f64)),
                ("axpy_ms", Json::Num(axpy.mean.as_secs_f64() * 1e3)),
                ("packed_ms", Json::Num(packed.mean.as_secs_f64() * 1e3)),
                ("axpy_gflops", Json::Num(axpy_gflops)),
                ("packed_gflops", Json::Num(packed_gflops)),
                ("speedup", Json::Num(speedup)),
            ])
        );
    }
}

fn svd_suite() {
    println!("\n== randomized SVD of W·X (calibration-time cost, Theorem 1) ==");
    let mut rng = Xoshiro256::new(3);
    for &(o, i, n, k) in &[(512usize, 192usize, 2048usize, 192usize), (576, 192, 2048, 192)] {
        let w = Mat::gaussian(o, i, 0.05, &mut rng);
        let x = Mat::gaussian(i, n, 1.0, &mut rng);
        let s = bench(
            &format!("left_sv_of_product {o}×{i} · {i}×{n}, k={k}"),
            Duration::from_millis(500),
            || {
                std::hint::black_box(rana::tensor::linalg::left_sv_of_product(
                    &w, &x, k, 2, 7,
                ));
            },
        );
        s.print();
    }
}

fn decode_suite() {
    println!("\n== single-token decode (native engine, llama-sim if trained) ==");
    let Ok(model) = rana::model::Model::load(&rana::model::model_dir("llama-sim")) else {
        eprintln!("llama-sim not trained; skipping");
        return;
    };
    let model = std::sync::Arc::new(model);
    let adapted = rana::adapters::AdaptedModel::unadapted(model);
    let mut cache = rana::model::KvCache::new(adapted.config());
    // Warm the cache to a realistic context.
    for t in 0..256u32 {
        rana::model::decode_step(&adapted, t % 256, &mut cache).expect("warmup fits max_seq");
    }
    let s = bench("decode_step @ ctx ≥256", Duration::from_millis(500), || {
        if cache.len() + 1 >= adapted.config().max_seq {
            cache.clear();
            for t in 0..256u32 {
                rana::model::decode_step(&adapted, t % 256, &mut cache)
                    .expect("warmup fits max_seq");
            }
        }
        std::hint::black_box(
            rana::model::decode_step(&adapted, 65, &mut cache).expect("guarded above"),
        );
    });
    s.print();
}

fn kernel_backend_suite() {
    println!(
        "\n== kernel backends: gemm/gemv/softmax per available backend \
         (dispatched: {}) ==",
        kernels::backend_name()
    );
    let mut rng = Xoshiro256::new(7);
    // One representative hot shape per op: a square packed GEMM, the
    // decode-path 512×2048 GEMV, and a long-context softmax row.
    let (gm, gk, gn) = (256usize, 256usize, 256usize);
    let ga = Mat::gaussian(gm, gk, 1.0, &mut rng);
    let gb = Mat::gaussian(gk, gn, 1.0, &mut rng);
    let (vk, vn) = (512usize, 2048usize);
    let vx: Vec<f32> = (0..vk).map(|_| rng.gaussian()).collect();
    let vb = Mat::gaussian(vk, vn, 1.0, &mut rng);
    let sn = 4096usize;
    let logits: Vec<f32> = (0..sn).map(|_| 4.0 * rng.gaussian()).collect();

    let mut generic_ms: std::collections::HashMap<&'static str, f64> =
        std::collections::HashMap::new();
    for kern in kernels::available() {
        let name = kern.name();
        let mut emit = |op: &str, ms: f64, gflops: f64| {
            println!(
                "{}",
                Json::obj(vec![
                    ("bench", Json::str("kernel_backend")),
                    ("backend", Json::str(name)),
                    ("op", Json::str(op)),
                    ("ms", Json::Num(ms)),
                    ("gflops", Json::Num(gflops)),
                ])
            );
        };

        let mut out = Mat::zeros(gm, gn);
        let s = bench(&format!("[{name}] gemm {gm}×{gk}×{gn}"), Duration::from_millis(300), || {
            gemm_packed_with(kern, gm, gk, gn, &ga.data, &gb.data, &mut out.data, 1.0, 0.0);
            std::hint::black_box(&out);
        });
        s.print();
        let ms = s.mean.as_secs_f64() * 1e3;
        let gemm_gflops = 2.0 * (gm * gk * gn) as f64 / s.mean.as_secs_f64() / 1e9;
        emit("gemm", ms, gemm_gflops);
        if name == "generic" {
            generic_ms.insert("gemm", ms);
        } else if let Some(&base) = generic_ms.get("gemm") {
            println!("    → {:.2}× vs generic", base / ms);
        }

        let mut vout = vec![0.0f32; vn];
        let s = bench(&format!("[{name}] gemv {vk}×{vn}"), Duration::from_millis(300), || {
            kern.gemv(&mut vout, &vx, &vb.data, vk, vn, 1.0, 0.0);
            std::hint::black_box(&vout);
        });
        s.print();
        let ms = s.mean.as_secs_f64() * 1e3;
        let gemv_gflops = 2.0 * (vk * vn) as f64 / s.mean.as_secs_f64() / 1e9;
        emit("gemv", ms, gemv_gflops);
        if name == "generic" {
            generic_ms.insert("gemv", ms);
        } else if let Some(&base) = generic_ms.get("gemv") {
            println!("    → {:.2}× vs generic", base / ms);
        }

        let mut srow = logits.clone();
        let s = bench(&format!("[{name}] softmax n={sn}"), Duration::from_millis(300), || {
            srow.copy_from_slice(&logits);
            kern.softmax(&mut srow);
            std::hint::black_box(&srow);
        });
        s.print();
        let ms = s.mean.as_secs_f64() * 1e3;
        // ~1 exp + 2 passes per element; count exp as one "flop" for a
        // stable per-backend rate, not a hardware-true FLOP count.
        let softmax_gflops = 3.0 * sn as f64 / s.mean.as_secs_f64() / 1e9;
        emit("softmax", ms, softmax_gflops);
        if name == "generic" {
            generic_ms.insert("softmax", ms);
        } else if let Some(&base) = generic_ms.get("softmax") {
            println!("    → {:.2}× vs generic", base / ms);
        }
    }
}

fn main() {
    let args = Args::from_env();
    if args.filter_matches("gemv") {
        gemv_suite();
    }
    if args.filter_matches("gemm") {
        gemm_suite();
    }
    if args.filter_matches("svd") {
        svd_suite();
    }
    if args.filter_matches("decode") {
        decode_suite();
    }
    if args.filter_matches("kernel") {
        kernel_backend_suite();
    }
}
