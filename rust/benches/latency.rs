//! Fig. 1b — accuracy vs per-token decode latency (paper §5.3 "Latency
//! Evaluations"), on the native engine where masked skipping is real work
//! reduction, not simulated.
//!
//! Protocol mirrors the paper: decode 492 tokens with initial contexts of
//! 1..1000 (clamped to the model's max_seq here), per-token wall-clock
//! averaged across contexts; RaNA vs CATS vs dense at several rates.
//!
//! Usage: cargo bench --bench latency [-- fig1b|serving|load|gemm] [--fast]

use std::sync::Arc;
use std::time::{Duration, Instant};

use rana::adapters::calibrate::Method;
use rana::bench::experiments::{Opts, Workbench};
use rana::bench::harness::{bench, Table};
use rana::data::tasks::all_suites;
use rana::model::{decode_step, KvCache};
use rana::util::cli::Args;
use rana::util::json::Json;

fn decode_latency<B: rana::model::BlockOps>(
    b: &B,
    contexts: &[usize],
    decode_len: usize,
    heldout: &[u32],
) -> Duration {
    let max_seq = b.config().max_seq;
    let mut total = Duration::ZERO;
    let mut tokens_timed = 0usize;
    for &ctx in contexts {
        let ctx = ctx.min(max_seq.saturating_sub(decode_len + 1)).max(1);
        let mut cache = KvCache::new(b.config());
        // Prefill (not timed — paper times decoding).
        let mut logits = Vec::new();
        for &t in &heldout[..ctx] {
            logits = decode_step(b, t, &mut cache).expect("ctx clamped below max_seq");
        }
        let n = decode_len.min(max_seq - ctx - 1);
        let t0 = Instant::now();
        for _ in 0..n {
            let next = rana::eval::argmax(&logits) as u32;
            logits = decode_step(b, next, &mut cache).expect("n clamped below max_seq");
        }
        total += t0.elapsed();
        tokens_timed += n;
    }
    total / tokens_timed.max(1) as u32
}

fn fig1b(opts: Opts, decode_len: usize) -> anyhow::Result<()> {
    println!("\n== Fig.1b — accuracy vs per-token decode latency (native engine) ==");
    let wb = Workbench::load("llama-sim", opts)?;
    let contexts = [1usize, 128, 256, 448];
    let g = rana::data::grammar();
    let suites = all_suites(&g, opts.items, opts.seed ^ 0x7A5C);

    let mut t = Table::new(&["Method", "Compression", "per-token latency", "Avg Acc"]);
    let dense = wb.dense();
    let lat = decode_latency(&dense, &contexts, decode_len, &wb.heldout);
    let accs = rana::eval::task_accuracies(&dense, &suites);
    let avg = accs.iter().sum::<f64>() / accs.len() as f64;
    t.row(vec!["dense".into(), "0%".into(), format!("{lat:.1?}"), format!("{:.2}%", avg * 100.0)]);

    for method in [Method::Rana, Method::Cats] {
        for &rate in &[0.2, 0.35, 0.5] {
            let (m, rep) = wb.adapt(method, rate);
            let lat = decode_latency(&m, &contexts, decode_len, &wb.heldout);
            let accs = rana::eval::task_accuracies(&m, &suites);
            let avg = accs.iter().sum::<f64>() / accs.len() as f64;
            t.row(vec![
                method.label().into(),
                format!("{:.1}%", rep.total_compression * 100.0),
                format!("{lat:.1?}"),
                format!("{:.2}%", avg * 100.0),
            ]);
        }
    }
    t.print();
    println!("(masked GEMV realizes FLOP savings: latency should fall with compression for RaNA)");
    Ok(())
}

/// Serving-path benches: (1) decode throughput of the iteration-level
/// batched path vs the per-thread request-level baseline, across batch
/// sizes, emitted as JSON rows; (2) coordinator + batcher overhead vs raw
/// engine. Runs on trained artifacts when present, else a seeded random
/// init (latency is shape-bound), so it doubles as the CI smoke bench.
fn serving(opts: Opts) -> anyhow::Result<()> {
    use rana::adapters::AdaptedModel;
    use rana::coordinator::batcher::{call, score_req, Batcher, BudgetPolicy};
    use rana::coordinator::engine::{Engine, NativeEngine};

    println!("\n== Serving: batched decode vs per-thread baseline ==");
    let model = Arc::new(rana::model::load_or_random("llama-sim", 0xDECADE)?);
    let adapted = Arc::new(AdaptedModel::unadapted(Arc::clone(&model)));
    let gen_tokens = if opts.items <= 16 { 16 } else { 48 };
    for batch in [1usize, 2, 4, 8] {
        let prompts: Vec<(String, usize)> = (0..batch)
            .map(|i| (format!("the dax lopa the fep number {i} ."), gen_tokens))
            .collect();
        let engine = NativeEngine::new(Arc::clone(&adapted)).with_decode_capacity(batch);
        // Warm both paths (first run pays cache/page faults).
        let _ = engine.generate_batch_threads(&prompts);
        let _ = engine.generate_batch(&prompts);
        let t0 = Instant::now();
        let _ = engine.generate_batch_threads(&prompts);
        let threads = t0.elapsed();
        let t0 = Instant::now();
        let _ = engine.generate_batch(&prompts);
        let batched = t0.elapsed();
        let toks = (batch * gen_tokens) as f64;
        let threads_tps = toks / threads.as_secs_f64().max(1e-12);
        let batched_tps = toks / batched.as_secs_f64().max(1e-12);
        println!(
            "batch {batch}: per-thread {threads_tps:7.0} tok/s   \
             batched {batched_tps:7.0} tok/s   ({:.2}x)",
            batched_tps / threads_tps
        );
        println!(
            "{}",
            Json::obj(vec![
                ("bench", Json::str("serving_decode")),
                ("kernel", Json::str(rana::tensor::kernels::backend_name())),
                ("batch", Json::Num(batch as f64)),
                ("gen_tokens", Json::Num(gen_tokens as f64)),
                ("threads_tok_s", Json::Num(threads_tps)),
                ("batched_tok_s", Json::Num(batched_tps)),
                ("speedup", Json::Num(batched_tps / threads_tps)),
            ])
        );
    }

    println!("\n== Serving: paged KV cache (50% memory, shared prefix) vs dense slots ==");
    {
        use rana::coordinator::metrics::Metrics;
        use rana::data::tokenizer;
        use std::sync::atomic::Ordering;
        let g = rana::data::grammar();
        let prefix = rana::coordinator::workload::shared_prefix(&g, 24);
        let batch = 8usize;
        let prompts: Vec<(String, usize)> = (0..batch)
            .map(|i| (format!("{prefix}about request {i} :"), gen_tokens))
            .collect();
        let bs = 16usize;
        let dense_blocks = batch * model.cfg.max_seq.div_ceil(bs);
        let dense_engine = NativeEngine::new(Arc::clone(&adapted))
            .with_dense_cache()
            .with_decode_capacity(batch);
        let paged_engine = NativeEngine::new(Arc::clone(&adapted))
            .with_paged_cache(bs, dense_blocks / 2)
            .with_decode_capacity(batch);
        let metrics = Arc::new(Metrics::new());
        paged_engine.set_metrics(Arc::clone(&metrics));
        // Warm both paths; the paged warm run also fills the engine's
        // persistent prefix trie, so the timed run measures reuse.
        let _ = dense_engine.generate_batch(&prompts);
        let _ = paged_engine.generate_batch(&prompts);
        let hits_before = metrics.prefix_hit_tokens.load(Ordering::Relaxed);
        let t0 = Instant::now();
        let dense_out = dense_engine.generate_batch(&prompts);
        let dense_t = t0.elapsed();
        let t0 = Instant::now();
        let paged_out = paged_engine.generate_batch(&prompts);
        let paged_t = t0.elapsed();
        let hits = metrics.prefix_hit_tokens.load(Ordering::Relaxed) - hits_before;
        let prompt_tokens: usize =
            prompts.iter().map(|(p, _)| tokenizer::encode(p, true).len()).sum();
        let toks = (batch * gen_tokens) as f64;
        let dense_tps = toks / dense_t.as_secs_f64().max(1e-12);
        let paged_tps = toks / paged_t.as_secs_f64().max(1e-12);
        println!(
            "dense {dense_tps:7.0} tok/s   paged@50% mem {paged_tps:7.0} tok/s ({:.2}x)   \
             prefix hits {hits}/{prompt_tokens} prompt tokens   texts identical: {}",
            paged_tps / dense_tps,
            dense_out == paged_out
        );
        println!(
            "{}",
            Json::obj(vec![
                ("bench", Json::str("serving_paged")),
                ("batch", Json::Num(batch as f64)),
                ("gen_tokens", Json::Num(gen_tokens as f64)),
                ("block_size", Json::Num(bs as f64)),
                ("pool_blocks", Json::Num((dense_blocks / 2) as f64)),
                ("dense_blocks", Json::Num(dense_blocks as f64)),
                ("dense_tok_s", Json::Num(dense_tps)),
                ("paged_tok_s", Json::Num(paged_tps)),
                ("speedup", Json::Num(paged_tps / dense_tps)),
                ("prefix_hit_tokens", Json::Num(hits as f64)),
                (
                    "prefix_hit_rate",
                    Json::Num(hits as f64 / prompt_tokens.max(1) as f64),
                ),
                (
                    "kv_blocks_in_use",
                    Json::Num(metrics.kv_blocks_in_use.load(Ordering::Relaxed) as f64),
                ),
                (
                    "kv_blocks_peak",
                    Json::Num(metrics.kv_blocks_peak.load(Ordering::Relaxed) as f64),
                ),
                ("texts_match_dense", Json::Bool(dense_out == paged_out)),
            ])
        );
    }

    // One calibration capture (fast mode trims it so the CI smoke stays
    // quick) serves BOTH the runtime-budget section and the speculative-
    // decode section below — the capture is tier-agnostic.
    let fast = opts.items <= 16;
    let calib_opts = rana::adapters::calibrate::CalibOptions {
        n_fit: opts.calib_fit.min(if fast { 384 } else { 1024 }),
        n_eval: 96,
        window: 96,
        seed: 0x5E12,
    };
    // Heldout sized for the layer-wise quality comparison below.
    let corpus = rana::data::generate_corpus(200_000, 20_000);
    let t0 = Instant::now();
    let calib = rana::adapters::calibrate::collect(&model, &corpus.train, &calib_opts);
    let calib_t = t0.elapsed();

    println!("\n== Serving: one runtime-budget engine vs the per-tier engine ladder ==");
    {
        use rana::adapters::calibrate::{self, Method};

        let rates: Vec<f64> = if fast { vec![0.35, 0.5] } else { vec![0.2, 0.35, 0.5] };
        let seq_len = 128usize;

        // ONE runtime-budget engine: calibration once, one weight set.
        let t0 = Instant::now();
        let (runtime, _) =
            calibrate::adapt_runtime(Arc::clone(&model), &calib, &rates, seq_len, 0x5E12);
        let runtime_build = calib_t + t0.elapsed();
        let runtime_bytes = runtime.adapter_param_bytes();
        let runtime_engine = NativeEngine::new(Arc::new(runtime));

        // The retained ladder baseline: one full adapt per tier (what the
        // pre-redesign server did at startup — N× search time, N× weights).
        let t0 = Instant::now();
        let ladder: Vec<(f64, Arc<AdaptedModel>)> = rates
            .iter()
            .map(|&r| {
                let (m, _) =
                    calibrate::adapt(Arc::clone(&model), &calib, Method::Rana, r, seq_len, 0x5E12);
                (r, Arc::new(m))
            })
            .collect();
        let ladder_build = calib_t + t0.elapsed();
        let ladder_bytes: usize = ladder.iter().map(|(_, m)| m.adapter_param_bytes()).sum();

        println!(
            "startup: runtime {runtime_build:?} vs ladder {ladder_build:?} ({:.2}x)   \
             adapter memory: runtime {:.1} MB vs ladder {:.1} MB ({:.2}x)",
            ladder_build.as_secs_f64() / runtime_build.as_secs_f64().max(1e-9),
            runtime_bytes as f64 / 1e6,
            ladder_bytes as f64 / 1e6,
            ladder_bytes as f64 / runtime_bytes.max(1) as f64,
        );
        println!(
            "{}",
            Json::obj(vec![
                ("bench", Json::str("serving_budget")),
                ("kind", Json::str("startup")),
                ("tiers", Json::Num(rates.len() as f64)),
                ("runtime_startup_s", Json::Num(runtime_build.as_secs_f64())),
                ("ladder_startup_s", Json::Num(ladder_build.as_secs_f64())),
                ("runtime_adapter_mb", Json::Num(runtime_bytes as f64 / 1e6)),
                ("ladder_adapter_mb", Json::Num(ladder_bytes as f64 / 1e6)),
                (
                    "memory_ratio",
                    Json::Num(ladder_bytes as f64 / runtime_bytes.max(1) as f64),
                ),
            ])
        );

        let prompts: Vec<(String, usize)> = (0..4)
            .map(|i| (format!("the dax lopa the fep number {i} ."), gen_tokens))
            .collect();
        for (i, &rate) in rates.iter().enumerate() {
            runtime_engine.set_budget(rate);
            let _ = runtime_engine.generate_batch(&prompts); // warm
            let t0 = Instant::now();
            let rt_out = runtime_engine.generate_batch(&prompts);
            let rt_t = t0.elapsed();
            let tier_engine = NativeEngine::new(Arc::clone(&ladder[i].1));
            let _ = tier_engine.generate_batch(&prompts); // warm
            let t0 = Instant::now();
            let tier_out = tier_engine.generate_batch(&prompts);
            let tier_t = t0.elapsed();
            let toks = (prompts.len() * gen_tokens) as f64;
            let rt_tps = toks / rt_t.as_secs_f64().max(1e-12);
            let tier_tps = toks / tier_t.as_secs_f64().max(1e-12);
            let matches = rt_out == tier_out;
            println!(
                "tier {rate:.2}: runtime {rt_tps:7.0} tok/s   static tier {tier_tps:7.0} \
                 tok/s   texts match static: {matches}"
            );
            println!(
                "{}",
                Json::obj(vec![
                    ("bench", Json::str("serving_budget")),
                    ("kind", Json::str("tier")),
                    ("rate", Json::Num(rate)),
                    ("gen_tokens", Json::Num(gen_tokens as f64)),
                    ("runtime_tok_s", Json::Num(rt_tps)),
                    ("ladder_tok_s", Json::Num(tier_tps)),
                    ("texts_match_static", Json::Bool(matches)),
                ])
            );
        }
        runtime_engine.set_budget(0.0);
    }

    println!("\n== Serving: self-speculative decode (draft at 0.5 budget, verify at target) ==");
    {
        use rana::adapters::calibrate;
        use rana::coordinator::metrics::Metrics;
        use std::sync::atomic::Ordering;

        let draft_rate = 0.5;
        let spec_k = 4usize;
        // ONE runtime-budget model over the shared calibration capture:
        // ambient rate 0 (dense target) with the draft tier calibrated —
        // speculation turns the cheap tier into a decode speedup with
        // bit-exact full-budget text.
        let (runtime, _) =
            calibrate::adapt_runtime(Arc::clone(&model), &calib, &[draft_rate], 128, 0x5E12);
        let runtime = Arc::new(runtime);
        let batch = 4usize;
        let prompts: Vec<(String, usize)> = (0..batch)
            .map(|i| (format!("the dax lopa the fep number {i} ."), gen_tokens))
            .collect();
        let base_engine =
            NativeEngine::new(Arc::clone(&runtime)).with_decode_capacity(batch);
        let spec_engine = NativeEngine::new(Arc::clone(&runtime))
            .with_decode_capacity(batch)
            .with_spec(spec_k, draft_rate);
        let metrics = Arc::new(Metrics::new());
        spec_engine.set_metrics(Arc::clone(&metrics));
        // Warm both paths (the spec warm run also measures acceptance).
        let _ = base_engine.generate_batch(&prompts);
        let _ = spec_engine.generate_batch(&prompts);
        let t0 = Instant::now();
        let base_out = base_engine.generate_batch(&prompts);
        let base_t = t0.elapsed();
        let t0 = Instant::now();
        let spec_out = spec_engine.generate_batch(&prompts);
        let spec_t = t0.elapsed();
        let toks = (batch * gen_tokens) as f64;
        let base_tps = toks / base_t.as_secs_f64().max(1e-12);
        let spec_tps = toks / spec_t.as_secs_f64().max(1e-12);
        let drafts = metrics.draft_tokens.load(Ordering::Relaxed);
        let accepted = metrics.accepted_tokens.load(Ordering::Relaxed);
        let rollbacks = metrics.spec_rollbacks.load(Ordering::Relaxed);
        let texts_match = base_out == spec_out;
        println!(
            "non-spec {base_tps:7.0} tok/s   spec(k={spec_k}) {spec_tps:7.0} tok/s \
             ({:.2}x)   acceptance {:.2} ({accepted}/{drafts})   rollbacks {rollbacks}   \
             texts identical: {texts_match}",
            spec_tps / base_tps,
            metrics.spec_acceptance(),
        );
        println!(
            "{}",
            Json::obj(vec![
                ("bench", Json::str("serving_spec")),
                ("batch", Json::Num(batch as f64)),
                ("gen_tokens", Json::Num(gen_tokens as f64)),
                ("spec_k", Json::Num(spec_k as f64)),
                ("draft_rate", Json::Num(draft_rate)),
                ("base_tok_s", Json::Num(base_tps)),
                ("spec_tok_s", Json::Num(spec_tps)),
                ("speedup", Json::Num(spec_tps / base_tps)),
                ("draft_tokens", Json::Num(drafts as f64)),
                ("accepted_tokens", Json::Num(accepted as f64)),
                ("acceptance_rate", Json::Num(metrics.spec_acceptance())),
                ("spec_rollbacks", Json::Num(rollbacks as f64)),
                ("texts_match", Json::Bool(texts_match)),
            ])
        );
    }

    println!("\n== Serving: layer-wise allocation vs uniform at matched FLOP budgets ==");
    {
        use rana::adapters::calibrate;
        use rana::eval::perplexity;

        let rates: Vec<f64> = if fast { vec![0.35, 0.5] } else { vec![0.2, 0.35, 0.5] };
        let seq_len = 128usize;
        // Same calibration capture, same seeds: the only difference is how
        // each tier's rank is spread over the layers.
        let (uniform, _) =
            calibrate::adapt_runtime(Arc::clone(&model), &calib, &rates, seq_len, 0x5E12);
        let (layered, reports) = calibrate::adapt_runtime_layerwise(
            Arc::clone(&model),
            &calib,
            &rates,
            seq_len,
            0x5E12,
            None,
        );
        let uniform = Arc::new(uniform);
        let layered = Arc::new(layered);
        let u_engine = NativeEngine::new(Arc::clone(&uniform));
        let l_engine = NativeEngine::new(Arc::clone(&layered));
        let eval_tokens =
            corpus.heldout.len().saturating_sub(1).min(if fast { 2_048 } else { 8_192 });
        let prompts: Vec<(String, usize)> = (0..4)
            .map(|i| (format!("the dax lopa the fep number {i} ."), gen_tokens))
            .collect();
        for (i, &rate) in rates.iter().enumerate() {
            uniform.set_budget(rate);
            layered.set_budget(rate);
            // Mean-preserving allocation over affine component budgets ⇒
            // matched FLOPs by construction; measured here, asserted in CI.
            let u_flops = uniform.decode_flops(seq_len).total;
            let l_flops = layered.decode_flops(seq_len).total;
            let flops_matched = (l_flops - u_flops).abs() / u_flops < 0.06;
            // Quality at equal FLOPs: held-out perplexity (lower wins).
            let u_ppl = perplexity(&*uniform, &corpus.heldout, eval_tokens, 96);
            let l_ppl = perplexity(&*layered, &corpus.heldout, eval_tokens, 96);
            // Throughput at the same knob value.
            let _ = u_engine.generate_batch(&prompts); // warm
            let t0 = Instant::now();
            let _ = u_engine.generate_batch(&prompts);
            let u_t = t0.elapsed();
            let _ = l_engine.generate_batch(&prompts); // warm
            let t0 = Instant::now();
            let _ = l_engine.generate_batch(&prompts);
            let l_t = t0.elapsed();
            let toks = (prompts.len() * gen_tokens) as f64;
            let u_tps = toks / u_t.as_secs_f64().max(1e-12);
            let l_tps = toks / l_t.as_secs_f64().max(1e-12);
            let lr = &reports[i].layer_rates;
            let spread = lr.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
                - lr.iter().cloned().fold(f64::INFINITY, f64::min);
            println!(
                "tier {rate:.2}: ppl uniform {u_ppl:8.2} vs layerwise {l_ppl:8.2} \
                 ({})   tok/s {u_tps:7.0} vs {l_tps:7.0}   flops matched: \
                 {flops_matched}   allocation spread {spread:.3}",
                if l_ppl <= u_ppl { "layerwise wins" } else { "uniform wins" },
            );
            println!(
                "{}",
                Json::obj(vec![
                    ("bench", Json::str("serving_layerwise")),
                    ("rate", Json::Num(rate)),
                    ("eval_tokens", Json::Num(eval_tokens as f64)),
                    ("uniform_ppl", Json::Num(u_ppl)),
                    ("layerwise_ppl", Json::Num(l_ppl)),
                    ("ppl_win", Json::Bool(l_ppl <= u_ppl)),
                    ("uniform_tok_s", Json::Num(u_tps)),
                    ("layerwise_tok_s", Json::Num(l_tps)),
                    ("uniform_flops", Json::Num(u_flops)),
                    ("layerwise_flops", Json::Num(l_flops)),
                    ("flops_matched", Json::Bool(flops_matched)),
                    ("allocation_spread", Json::Num(spread)),
                ])
            );
        }
        uniform.set_budget(0.0);
        layered.set_budget(0.0);
    }

    println!("\n== Serving: measured FLOPs/token vs the analytic schedule ==");
    {
        use rana::adapters::calibrate;
        use rana::data::tokenizer;
        use rana::flops::measured;

        let rates: Vec<f64> = if fast { vec![0.35, 0.5] } else { vec![0.2, 0.35, 0.5] };
        let (runtime, _) =
            calibrate::adapt_runtime(Arc::clone(&model), &calib, &rates, 128, 0x5E12);
        let runtime = Arc::new(runtime);
        // Dense KV slots on purpose: the paged engine's prefix trie would
        // reuse cached prompt blocks across tiers and skip their measured
        // prefill FLOPs, skewing the tier-to-tier comparison.
        let flops_engine = NativeEngine::new(Arc::clone(&runtime)).with_dense_cache();
        let prompts: Vec<(String, usize)> = (0..4)
            .map(|i| (format!("the dax lopa the fep number {i} ."), gen_tokens))
            .collect();
        // Measured-convention positions per sequence: every forward pass —
        // prompt prefill included — except the final emitted token.
        let steps_of = |texts: &[String]| -> Vec<usize> {
            texts.iter().map(|t| tokenizer::encode(t, true).len().saturating_sub(1)).collect()
        };

        let mut tiers = vec![0.0];
        tiers.extend(rates.iter().copied());
        let mut dense_fpt = 0.0f64;
        for &rate in &tiers {
            flops_engine.set_budget(rate);
            let _ = flops_engine.generate_batch(&prompts); // warm (not measured)
            let before = measured::snapshot();
            let out = flops_engine.generate_batch(&prompts);
            let delta = measured::snapshot().delta_since(&before);
            let steps = steps_of(&out);
            let analytic: f64 = steps
                .iter()
                .map(|&s| {
                    if rate == 0.0 {
                        runtime.measured_dense_flops(s)
                    } else {
                        runtime.runtime_decode_flops(s, rate)
                    }
                })
                .sum();
            let rel_err = (delta.flops as f64 - analytic).abs() / analytic.max(1.0);
            let within = rel_err <= 0.05;
            let fpt = delta.flops as f64 / steps.iter().sum::<usize>().max(1) as f64;
            if rate == 0.0 {
                dense_fpt = fpt;
            }
            let compression = 1.0 - fpt / dense_fpt.max(1.0);
            println!(
                "tier {rate:.2}: measured {:.3} MFLOPs/tok   analytic err {:.2}%   \
                 compression vs dense {:.1}%   within 5%: {within}",
                fpt / 1e6,
                rel_err * 100.0,
                compression * 100.0,
            );
            println!(
                "{}",
                Json::obj(vec![
                    ("bench", Json::str("serving_flops")),
                    ("kind", Json::str("tier")),
                    ("rate", Json::Num(rate)),
                    ("gen_tokens", Json::Num(gen_tokens as f64)),
                    ("measured_flops", Json::Num(delta.flops as f64)),
                    ("measured_bytes", Json::Num(delta.bytes as f64)),
                    ("analytic_flops", Json::Num(analytic)),
                    ("flops_per_token", Json::Num(fpt)),
                    ("measured_compression", Json::Num(compression)),
                    ("rel_err", Json::Num(rel_err)),
                    ("measured_vs_analytic_within_5pct", Json::Bool(within)),
                ])
            );
        }

        // Counter-overhead contract: one relaxed add per kernel call must
        // stay in the noise. Best-of-3 either way, dense budget.
        flops_engine.set_budget(0.0);
        let toks = (prompts.len() * gen_tokens) as f64;
        let (mut best_off, mut best_on) = (0.0f64, 0.0f64);
        for _ in 0..3 {
            measured::set_enabled(false);
            let t0 = Instant::now();
            let _ = flops_engine.generate_batch(&prompts);
            best_off = best_off.max(toks / t0.elapsed().as_secs_f64().max(1e-12));
            measured::set_enabled(true);
            let t0 = Instant::now();
            let _ = flops_engine.generate_batch(&prompts);
            best_on = best_on.max(toks / t0.elapsed().as_secs_f64().max(1e-12));
        }
        let overhead_pct = (best_off / best_on.max(1e-12) - 1.0) * 100.0;
        let overhead_ok = overhead_pct <= 3.0;
        println!(
            "counters on {best_on:7.0} tok/s   off {best_off:7.0} tok/s   \
             overhead {overhead_pct:.2}% (target ≤ 3% — DESIGN.md §2i)"
        );
        println!(
            "{}",
            Json::obj(vec![
                ("bench", Json::str("serving_flops")),
                ("kind", Json::str("overhead")),
                ("gen_tokens", Json::Num(gen_tokens as f64)),
                ("counters_on_tok_s", Json::Num(best_on)),
                ("counters_off_tok_s", Json::Num(best_off)),
                ("overhead_pct", Json::Num(overhead_pct)),
                ("overhead_within_3pct", Json::Bool(overhead_ok)),
            ])
        );
    }

    println!("\n== Serving: request-tracing overhead + TTFT/ITL quantiles ==");
    {
        use rana::coordinator::batcher::generate_req;

        let batch = 4usize;
        let n_req = 12usize;
        let engine: Arc<dyn Engine> = Arc::new(
            NativeEngine::new(Arc::clone(&adapted)).with_decode_capacity(batch),
        );
        // Drive the same closed-loop generate burst with the tracer's event
        // log + ring ON vs OFF (timing scalars are always recorded — they
        // back the response timing blocks). Returns tok/s and the batcher
        // so the traced run's histograms can be read back.
        let run = |traced: bool| {
            let batcher =
                Arc::new(Batcher::new(Arc::clone(&engine), BudgetPolicy::fixed(0.0), batch));
            batcher.tracer().set_enabled(traced);
            let tx = batcher.submitter();
            let b2 = Arc::clone(&batcher);
            std::thread::spawn(move || b2.run());
            let _ = call(&tx, generate_req("the dax lopa warm .", gen_tokens)); // warm
            let t0 = Instant::now();
            let handles: Vec<_> = (0..n_req)
                .map(|i| {
                    let tx = tx.clone();
                    std::thread::spawn(move || {
                        call(&tx, generate_req(&format!("the dax lopa number {i} ."), gen_tokens))
                            .unwrap()
                    })
                })
                .collect();
            let mut toks = 0usize;
            for h in handles {
                toks += h.join().unwrap().get_usize("tokens").unwrap();
            }
            let tps = toks as f64 / t0.elapsed().as_secs_f64().max(1e-12);
            batcher.close();
            (tps, batcher)
        };
        let (no_trace_tps, _) = run(false);
        let (trace_tps, traced) = run(true);
        let m = &traced.metrics;
        let overhead_pct = (no_trace_tps / trace_tps.max(1e-12) - 1.0) * 100.0;
        println!(
            "traced {trace_tps:7.0} tok/s   untraced {no_trace_tps:7.0} tok/s   \
             overhead {overhead_pct:.2}% (target < 2% — DESIGN.md §2g)"
        );
        println!(
            "TTFT p50/p95/p99: {}/{}/{} µs   ITL p50/p95/p99: {}/{}/{} µs   \
             queue p50: {} µs",
            m.ttft_quantile_us(0.50),
            m.ttft_quantile_us(0.95),
            m.ttft_quantile_us(0.99),
            m.itl_quantile_us(0.50),
            m.itl_quantile_us(0.95),
            m.itl_quantile_us(0.99),
            m.queue_wait_quantile_us(0.50),
        );
        println!(
            "{}",
            Json::obj(vec![
                ("bench", Json::str("serving_trace")),
                ("batch", Json::Num(batch as f64)),
                ("requests", Json::Num(n_req as f64)),
                ("gen_tokens", Json::Num(gen_tokens as f64)),
                ("trace_tok_s", Json::Num(trace_tps)),
                ("no_trace_tok_s", Json::Num(no_trace_tps)),
                ("trace_overhead_pct", Json::Num(overhead_pct)),
                ("ttft_p50_us", Json::Num(m.ttft_quantile_us(0.50) as f64)),
                ("ttft_p95_us", Json::Num(m.ttft_quantile_us(0.95) as f64)),
                ("ttft_p99_us", Json::Num(m.ttft_quantile_us(0.99) as f64)),
                ("itl_p50_us", Json::Num(m.itl_quantile_us(0.50) as f64)),
                ("itl_p95_us", Json::Num(m.itl_quantile_us(0.95) as f64)),
                ("itl_p99_us", Json::Num(m.itl_quantile_us(0.99) as f64)),
                ("timelines_recorded", Json::Num(traced.tracer().ring_len() as f64)),
            ])
        );
    }

    println!("\n== Serving: SLO scheduler (chunked prefill + controller) vs FIFO ==");
    {
        use rana::adapters::calibrate;
        use rana::coordinator::batcher::generate_req;
        use rana::coordinator::protocol::Request;
        use rana::sched::{Priority, SloConfig, SloController};
        use rana::util::rng::Xoshiro256;
        use std::sync::atomic::Ordering;

        // Runtime-budget model so the SLO controller's rank knob is live
        // (the controller clamps to a no-op on fixed-budget engines).
        let tiers = vec![0.35, 0.5];
        let (runtime, _) =
            calibrate::adapt_runtime(Arc::clone(&model), &calib, &tiers, 128, 0x5E12);
        let runtime = Arc::new(runtime);

        // Bursty long-prompt mix, built once so both configs replay the
        // byte-identical request sequence: ~60% of requests carry a long
        // sampled context (prefill-dominated), the rest are short.
        let n_req = if fast { 24usize } else { 48 };
        let batch = 4usize;
        let slo_tokens = 8usize;
        let g = rana::data::grammar();
        let mut rng = Xoshiro256::new(0x510);
        let specs: Vec<(String, Priority, Option<String>)> = (0..n_req)
            .map(|i| {
                let long = rng.f64() < 0.6;
                let mut prompt = String::new();
                if long {
                    prompt.push_str("ctx:");
                    for _ in 0..40 {
                        prompt.push(' ');
                        prompt.push_str(&g.entities[rng.below(g.entities.len())]);
                    }
                    prompt.push(' ');
                }
                prompt.push_str(&format!("about request {i} :"));
                let prio = match rng.below(4) {
                    0 => Priority::High,
                    1 => Priority::Low,
                    _ => Priority::Normal,
                };
                (prompt, prio, Some(format!("t{}", rng.below(2))))
            })
            .collect();

        let quant = |samples: &mut Vec<f64>, p: f64| -> f64 {
            if samples.is_empty() {
                return 0.0;
            }
            samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            samples[(((samples.len() - 1) as f64) * p).round() as usize]
        };

        // One load run: fire the spec list in bursts of 6 (30 ms off-gap)
        // against a batcher configured FIFO (chunk 1, untagged, no
        // controller) or chunked+SLO (chunk 64, priority/tenant tags, SLO
        // controller on the rank knob). Quantiles come from the
        // per-response timing blocks, NOT batcher histograms — the
        // controller resets the metrics window on every decision.
        let run = |chunk: usize, slo: bool| {
            let engine: Arc<dyn Engine> = Arc::new(
                NativeEngine::new(Arc::clone(&runtime))
                    .with_decode_capacity(batch)
                    .with_prefill_chunk(chunk),
            );
            let mut b = Batcher::new(engine, BudgetPolicy::fixed(0.0), batch);
            if slo {
                // Ladder rung 0 is dense: the controller only trades
                // quality away when the latency targets are breached.
                let mut ladder = vec![0.0];
                ladder.extend_from_slice(&tiers);
                let cfg = SloConfig::new(
                    Some(Duration::from_millis(5)),
                    Some(Duration::from_millis(2)),
                    ladder,
                );
                b = b.with_slo_controller(SloController::new(cfg));
            }
            let batcher = Arc::new(b);
            let tx = batcher.submitter();
            let b2 = Arc::clone(&batcher);
            std::thread::spawn(move || b2.run());
            let _ = call(&tx, generate_req("the dax lopa warm .", slo_tokens)); // warm
            let t0 = Instant::now();
            let mut handles = Vec::with_capacity(n_req);
            for (i, (prompt, prio, tenant)) in specs.iter().enumerate() {
                if i > 0 && i % 6 == 0 {
                    std::thread::sleep(Duration::from_millis(30)); // burst gap
                }
                let mut req = generate_req(prompt, slo_tokens);
                if slo {
                    if let Request::Generate(gr) = &mut req {
                        gr.sched.priority = *prio;
                        gr.sched.tenant = tenant.clone();
                        if *prio == Priority::High {
                            gr.sched.deadline = Some(Duration::from_millis(50));
                        }
                    }
                }
                let tx = tx.clone();
                handles.push(std::thread::spawn(move || call(&tx, req).unwrap()));
            }
            let (mut ttfts, mut itls, mut toks) = (Vec::new(), Vec::new(), 0usize);
            for h in handles {
                let resp = h.join().unwrap();
                toks += resp.get_usize("tokens").unwrap_or(0);
                if let Ok(t) = resp.get("timing") {
                    if let Ok(us) = t.get_f64("ttft_us") {
                        ttfts.push(us);
                    }
                    if let Ok(us) = t.get_f64("itl_mean_us") {
                        itls.push(us);
                    }
                }
            }
            let tok_s = toks as f64 / t0.elapsed().as_secs_f64().max(1e-12);
            let retunes = batcher.metrics.slo_retunes.load(Ordering::Relaxed);
            batcher.close();
            (ttfts, itls, tok_s, retunes)
        };

        let mut rows = Vec::new();
        for (config, chunk, slo) in [("fifo", 1usize, false), ("chunked_slo", 64, true)] {
            let (mut ttfts, mut itls, tok_s, retunes) = run(chunk, slo);
            let (t50, t95, t99) =
                (quant(&mut ttfts, 0.50), quant(&mut ttfts, 0.95), quant(&mut ttfts, 0.99));
            let (i50, i95, i99) =
                (quant(&mut itls, 0.50), quant(&mut itls, 0.95), quant(&mut itls, 0.99));
            println!(
                "{config:>11}: TTFT p50/p95/p99 {t50:8.0}/{t95:8.0}/{t99:8.0} µs   \
                 ITL p50/p95/p99 {i50:6.0}/{i95:6.0}/{i99:6.0} µs   \
                 {tok_s:6.0} tok/s   retunes {retunes}"
            );
            println!(
                "{}",
                Json::obj(vec![
                    ("bench", Json::str("serving_slo")),
                    ("config", Json::str(config)),
                    ("prefill_chunk", Json::Num(chunk as f64)),
                    ("requests", Json::Num(n_req as f64)),
                    ("gen_tokens", Json::Num(slo_tokens as f64)),
                    ("ttft_p50_us", Json::Num(t50)),
                    ("ttft_p95_us", Json::Num(t95)),
                    ("ttft_p99_us", Json::Num(t99)),
                    ("itl_p50_us", Json::Num(i50)),
                    ("itl_p95_us", Json::Num(i95)),
                    ("itl_p99_us", Json::Num(i99)),
                    ("tok_s", Json::Num(tok_s)),
                    ("slo_retunes", Json::Num(retunes as f64)),
                ])
            );
            rows.push((t99, tok_s));
        }
        let (fifo_p99, fifo_tps) = rows[0];
        let (chunk_p99, chunk_tps) = rows[1];
        let ttft_win = chunk_p99 <= fifo_p99;
        let tps_ok = chunk_tps >= 0.9 * fifo_tps;
        println!(
            "p99 TTFT: chunked+SLO {chunk_p99:.0} µs vs FIFO {fifo_p99:.0} µs \
             ({:.2}x)   tok/s within 10%: {tps_ok}",
            fifo_p99 / chunk_p99.max(1.0),
        );
        println!(
            "{}",
            Json::obj(vec![
                ("bench", Json::str("serving_slo")),
                ("config", Json::str("compare")),
                ("fifo_ttft_p99_us", Json::Num(fifo_p99)),
                ("chunked_ttft_p99_us", Json::Num(chunk_p99)),
                ("chunked_ttft_p99_leq_fifo", Json::Bool(ttft_win)),
                ("fifo_tok_s", Json::Num(fifo_tps)),
                ("chunked_tok_s", Json::Num(chunk_tps)),
                ("tok_s_within_10pct", Json::Bool(tps_ok)),
            ])
        );
    }

    println!("\n== Serving-path overhead: coordinator vs raw engine ==");
    let engine: Arc<dyn Engine> = Arc::new(NativeEngine::new(Arc::clone(&adapted)));
    let texts: Vec<String> =
        (0..8).map(|i| format!("the dax lopa the fep number {i} .")).collect();

    // Raw engine batch.
    let t0 = Instant::now();
    let _ = engine.score_batch(&texts);
    let raw = t0.elapsed();

    // Through the coordinator.
    let batcher =
        Arc::new(Batcher::new(Arc::clone(&engine), BudgetPolicy::fixed(0.0), 8));
    let tx = batcher.submitter();
    let b2 = Arc::clone(&batcher);
    std::thread::spawn(move || b2.run());
    let t0 = Instant::now();
    let handles: Vec<_> = texts
        .iter()
        .map(|txt| {
            let tx = tx.clone();
            let txt = txt.clone();
            std::thread::spawn(move || call(&tx, score_req(&txt)).unwrap())
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let coordinated = t0.elapsed();
    println!("raw engine batch:   {raw:?}");
    println!("via coordinator:    {coordinated:?}");
    println!(
        "overhead: {:.1}%  (target < 10% — DESIGN.md §Perf L3)",
        (coordinated.as_secs_f64() / raw.as_secs_f64() - 1.0) * 100.0
    );
    Ok(())
}

/// Adaptive rank-budget controller under load (the future-work extension):
/// same overload burst with the runtime-budget controller on vs off — ONE
/// engine either way; only the shared budget scalar moves.
fn load_bench(_opts: Opts) -> anyhow::Result<()> {
    use rana::coordinator::batcher::Batcher;
    use rana::coordinator::workload::{run_load, Arrivals, Mix};
    use rana::coordinator::{build_engine, ServerConfig};

    println!("\n== Adaptive rank-budget controller under load ==");
    for adaptive in [false, true] {
        let cfg = ServerConfig {
            model: "llama-sim".into(),
            port: 0,
            max_batch: 4,
            adaptive_budget: adaptive,
            calib_fit: 512,
            ..ServerConfig::default()
        };
        let engine = build_engine(&cfg)?;
        let batcher = Arc::new(Batcher::new(engine, cfg.policy(), cfg.max_batch));
        let b2 = Arc::clone(&batcher);
        std::thread::spawn(move || b2.run());
        let report = run_load(
            &batcher,
            Arrivals::ClosedLoop { clients: 16 },
            Mix { generate_frac: 0.2, gen_tokens: 12, ..Mix::default() },
            64,
            0xF00D,
        );
        report.print(if adaptive {
            "adaptive controller ON "
        } else {
            "adaptive controller OFF"
        });
        use std::sync::atomic::Ordering;
        println!(
            "  budget_switches={} effective_rank_frac={:.3}",
            batcher.metrics.budget_switches.load(Ordering::Relaxed),
            batcher.metrics.effective_rank_frac_milli.load(Ordering::Relaxed) as f64 / 1000.0,
        );
        batcher.close();
    }
    println!("(expected: ON keeps p99 lower under overload by raising the shared budget)");
    Ok(())
}

/// Sequence-path (prefill) latency of the model's linear layers: packed
/// GEMM vs the seed's axpy-row loop at llama-sim shapes, per-window cost.
/// Needs no trained artifacts (weights are random; latency is shape-bound),
/// and emits JSON rows so the speedup lands in the bench trajectory.
fn seq_gemm() -> anyhow::Result<()> {
    use rana::tensor::gemm::{gemm_packed, gemm_rows_axpy};
    use rana::tensor::Mat;
    use rana::util::rng::Xoshiro256;

    println!("\n== sequence-path GEMM latency (prefill window × model linears) ==");
    let cfg = rana::model::ModelConfig::llama_sim();
    let (d, h, v) = (cfg.d_model, cfg.d_hidden, cfg.vocab);
    let t = 128usize; // prefill window of the PPL/calibration harness
    let mut rng = Xoshiro256::new(4);
    let shapes: &[(&str, usize, usize, usize)] = &[
        ("up/gate proj", t, d, h),
        ("down proj", t, h, d),
        ("fused qkv", t, d, 3 * d),
        ("lm head", t, d, v),
    ];
    for &(label, m, k, n) in shapes {
        let a = Mat::gaussian(m, k, 1.0, &mut rng);
        let b = Mat::gaussian(k, n, 1.0, &mut rng);
        let mut out = Mat::zeros(m, n);
        let axpy = bench(&format!("axpy {label} {m}×{k}×{n}"), Duration::from_millis(200), || {
            gemm_rows_axpy(m, k, n, &a.data, &b.data, &mut out.data, 1.0, 0.0);
            std::hint::black_box(&out);
        });
        axpy.print();
        let packed_label = format!("packed {label} {m}×{k}×{n}");
        let packed = bench(&packed_label, Duration::from_millis(200), || {
            gemm_packed(m, k, n, &a.data, &b.data, &mut out.data, 1.0, 0.0);
            std::hint::black_box(&out);
        });
        packed.print();
        let speedup = axpy.mean.as_secs_f64() / packed.mean.as_secs_f64();
        println!(
            "{}",
            Json::obj(vec![
                ("bench", Json::str("seq_gemm")),
                ("label", Json::str(label)),
                ("m", Json::Num(m as f64)),
                ("k", Json::Num(k as f64)),
                ("n", Json::Num(n as f64)),
                // Same field names/units as microbench's gemm rows, so one
                // trajectory consumer handles both suites.
                ("axpy_ms", Json::Num(axpy.mean.as_secs_f64() * 1e3)),
                ("packed_ms", Json::Num(packed.mean.as_secs_f64() * 1e3)),
                ("speedup", Json::Num(speedup)),
            ])
        );
    }
    Ok(())
}

fn main() {
    let args = Args::from_env();
    let mut opts = Opts::default();
    let mut decode_len = 128usize; // scaled-down default of the paper's 492
    if args.get_flag("fast") {
        opts.items = 16;
        opts.calib_fit = 512;
        decode_len = 48;
    }
    if args.get_flag("full") {
        opts.items = 100;
        decode_len = 400; // max_seq-bounded
    }
    let mut ran = false;
    if args.filter_matches("fig1b") {
        ran = true;
        if let Err(e) = fig1b(opts, decode_len) {
            eprintln!("fig1b: {e:#}");
        }
    }
    if args.filter_matches("serving") {
        ran = true;
        if let Err(e) = serving(opts) {
            eprintln!("serving: {e:#}");
        }
    }
    if args.filter_matches("load") {
        ran = true;
        if let Err(e) = load_bench(opts) {
            eprintln!("load: {e:#}");
        }
    }
    if args.filter_matches("gemm") {
        ran = true;
        if let Err(e) = seq_gemm() {
            eprintln!("gemm: {e:#}");
        }
    }
    if !ran {
        eprintln!("no latency bench matched");
    }
}
