//! Domain example: adapt a GeLU (Pythia-style) model with RaNA vs the
//! conventional neuron adapter — the paper's "general applicability to
//! non-SwiGLU activations" scenario (§5.3, Figs. 1c/4) — and inspect the
//! rank-contribution sparsity that makes it work (Fig. 2).
//!
//!     cargo run --release --example adapt_and_eval -- --model pythia-sim-m
//!
//! Requires `make artifacts`.

use rana::adapters::calibrate::Method;
use rana::adapters::rank_adapter::RankPrecomp;
use rana::bench::experiments::{Opts, Workbench};
use rana::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let model = args.get_str("model", "pythia-sim-m");
    let rate = args.get_f64("rate", 0.3);
    let opts = Opts { ppl_tokens: 10_000, items: 40, ..Opts::default() };
    let wb = Workbench::load(&model, opts)?;

    // 1. Rank-contribution sparsity (Fig. 2): is the B-masker justified?
    let layer = wb.model.cfg.n_layers / 2;
    let lc = &wb.calib.layers[layer];
    let pre = RankPrecomp::new(
        &wb.model.w.layers[layer].up.w,
        &lc.mlp_in_fit,
        &lc.mlp_in_eval,
        1,
    );
    let mut scores = pre.fit_scores_squared();
    let mean: f64 = scores.iter().map(|&s| s as f64).sum::<f64>() / scores.len() as f64;
    for s in scores.iter_mut() {
        *s /= mean as f32;
    }
    println!("== rank-contribution sparsity, {model} layer {layer} Up-projection ==");
    println!(
        "mass below 0.25×mean: {:.1}%  (paper Fig. 2: concentrated near 0, heavy tail)",
        rana::eval::mass_below(&scores, 0.25) * 100.0
    );

    // 2. RaNA vs conventional neuron adapter on a GeLU model.
    println!("\n== {model}: RaNA vs neuron adapter @ {:.0}% compression ==", rate * 100.0);
    let dense = wb.eval_row(&wb.dense(), None);
    println!("dense    : acc {:.2}%  ppl {:.3}", dense.avg * 100.0, dense.ppl);
    for method in [Method::Rana, Method::NeuronAdaptive] {
        let (m, rep) = wb.adapt(method, rate);
        let row = wb.eval_row(&m, Some(&rep));
        println!(
            "{:<9}: acc {:.2}%  ppl {:.3}  (achieved {:.1}%)",
            method.label(),
            row.avg * 100.0,
            row.ppl,
            rep.total_compression * 100.0
        );
    }
    println!("\nexpected shape: RaNA decays slower than the neuron adapter (Fig. 1c).");
    Ok(())
}
