//! End-to-end serving driver (DESIGN.md §5 E2E): proves all three layers
//! compose. Starts the coordinator over the **PJRT engine** (HLO artifacts
//! AOT-compiled from the JAX+Pallas model — python is not running), fires
//! a batched scoring + generation workload at it over TCP, and reports
//! latency/throughput; then repeats on the native engine with the adaptive
//! rank-budget ladder enabled.
//!
//!     cargo run --release --example serve_e2e
//!
//! Requires `make artifacts`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use rana::util::json::Json;

fn client_call(addr: &str, req: &Json) -> anyhow::Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{req}")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Ok(Json::parse(line.trim())?)
}

fn drive(addr: &str, label: &str, n_requests: usize) -> anyhow::Result<()> {
    // Wait for the server to come up.
    for _ in 0..600 {
        if TcpStream::connect(addr).is_ok() {
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    let g = rana::data::grammar();
    let mut rng = rana::util::rng::Xoshiro256::new(99);
    let texts: Vec<String> =
        (0..n_requests).map(|_| g.document(&mut rng)).collect();

    let t0 = Instant::now();
    let handles: Vec<_> = texts
        .into_iter()
        .map(|text| {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let t = Instant::now();
                let r = client_call(
                    &addr,
                    &Json::obj(vec![("op", Json::str("score")), ("text", Json::Str(text))]),
                )
                .expect("score call");
                (t.elapsed(), r)
            })
        })
        .collect();
    let mut lats: Vec<Duration> = Vec::new();
    for h in handles {
        let (lat, r) = h.join().unwrap();
        assert!(r.get_f64("logprob").is_ok(), "bad response {r}");
        lats.push(lat);
    }
    let wall = t0.elapsed();
    lats.sort();
    let gen = client_call(
        addr,
        &Json::obj(vec![
            ("op", Json::str("generate")),
            ("prompt", Json::str("about ")),
            ("tokens", Json::Num(24.0)),
        ]),
    )?;
    let stats = client_call(addr, &Json::obj(vec![("op", Json::str("stats"))]))?;

    println!("\n== {label} ==");
    println!(
        "{n_requests} scoring requests in {wall:?} → {:.1} req/s",
        n_requests as f64 / wall.as_secs_f64()
    );
    println!(
        "latency p50 {:?}  p99 {:?}",
        lats[lats.len() / 2],
        lats[lats.len() * 99 / 100]
    );
    println!("sample generation: {:?}", gen.get_str("text").unwrap_or("?"));
    println!("server stats: {stats}");
    Ok(())
}

fn run_server_and_drive(cfg: rana::coordinator::ServerConfig, label: &str) -> anyhow::Result<()> {
    let addr = format!("127.0.0.1:{}", cfg.port);
    let server = std::thread::spawn(move || rana::coordinator::serve(cfg));
    drive(&addr, label, 48)?;
    client_call(&addr, &Json::obj(vec![("op", Json::str("shutdown"))]))?;
    let _ = server.join();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    // Phase 1: PJRT engine — AOT HLO artifacts from the JAX+Pallas layers.
    run_server_and_drive(
        rana::coordinator::ServerConfig {
            model: "llama-sim".into(),
            port: 7071,
            max_batch: 4,
            target_compression: 0.0,
            adaptive_budget: true, // loads the rana AOT variant as tier 2
            engine: "pjrt".into(),
        },
        "PJRT engine (AOT jax+pallas artifacts, adaptive rana tier)",
    )?;

    // Phase 2: native engine with the adaptive rank-budget ladder.
    run_server_and_drive(
        rana::coordinator::ServerConfig {
            model: "llama-sim".into(),
            port: 7072,
            max_batch: 4,
            target_compression: 0.0,
            adaptive_budget: true,
            engine: "native".into(),
        },
        "native engine (adaptive rank-budget ladder dense/0.2/0.35/0.5)",
    )?;
    println!("\nserve_e2e OK — all three layers composed (L1 pallas → L2 jax → HLO → L3 rust).");
    Ok(())
}
