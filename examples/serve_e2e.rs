//! End-to-end serving driver (DESIGN.md §5 E2E): proves all three layers
//! compose. Starts the coordinator over the **PJRT engine** (HLO artifacts
//! AOT-compiled from the JAX+Pallas model — python is not running), fires
//! a batched scoring + generation workload at it over TCP, and reports
//! latency/throughput; then repeats on the native engine with the
//! runtime-budget controller enabled (ONE engine serving every tier).
//!
//!     cargo run --release --example serve_e2e [-- --native-only]
//!
//! The PJRT phase requires `make artifacts` and is skipped (with a
//! warning) when they are absent; the native phase runs anywhere.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use rana::util::json::Json;

fn client_call(addr: &str, req: &Json) -> anyhow::Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{req}")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Ok(Json::parse(line.trim())?)
}

fn drive(addr: &str, label: &str, n_requests: usize) -> anyhow::Result<()> {
    // Wait for the server to come up.
    for _ in 0..600 {
        if TcpStream::connect(addr).is_ok() {
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    let g = rana::data::grammar();
    let mut rng = rana::util::rng::Xoshiro256::new(99);
    let texts: Vec<String> =
        (0..n_requests).map(|_| g.document(&mut rng)).collect();

    let t0 = Instant::now();
    let handles: Vec<_> = texts
        .into_iter()
        .map(|text| {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let t = Instant::now();
                let r = client_call(
                    &addr,
                    &Json::obj(vec![("op", Json::str("score")), ("text", Json::Str(text))]),
                )
                .expect("score call");
                (t.elapsed(), r)
            })
        })
        .collect();
    let mut lats: Vec<Duration> = Vec::new();
    for h in handles {
        let (lat, r) = h.join().unwrap();
        assert!(r.get_f64("logprob").is_ok(), "bad response {r}");
        assert!(r.get_f64("budget").is_ok(), "responses must carry the budget: {r}");
        lats.push(lat);
    }
    let wall = t0.elapsed();
    lats.sort();
    let gen = client_call(
        addr,
        &Json::obj(vec![
            ("op", Json::str("generate")),
            ("prompt", Json::str("about ")),
            ("tokens", Json::Num(24.0)),
        ]),
    )?;
    let stats = client_call(addr, &Json::obj(vec![("op", Json::str("stats"))]))?;

    println!("\n== {label} ==");
    println!(
        "{n_requests} scoring requests in {wall:?} → {:.1} req/s",
        n_requests as f64 / wall.as_secs_f64()
    );
    println!(
        "latency p50 {:?}  p99 {:?}",
        lats[lats.len() / 2],
        lats[lats.len() * 99 / 100]
    );
    println!("sample generation: {:?}", gen.get_str("text").unwrap_or("?"));
    println!("server stats: {stats}");
    Ok(())
}

fn run_server_and_drive(cfg: rana::coordinator::ServerConfig, label: &str) -> anyhow::Result<()> {
    // Build the engine first so missing artifacts fail fast (instead of a
    // connect-retry stall against a server that never came up).
    let engine = rana::coordinator::build_engine(&cfg)?;
    let addr = format!("127.0.0.1:{}", cfg.port);
    let listener = std::net::TcpListener::bind(&addr)?;
    let server =
        std::thread::spawn(move || rana::coordinator::serve_on(listener, engine, cfg));
    drive(&addr, label, 48)?;
    client_call(&addr, &Json::obj(vec![("op", Json::str("shutdown"))]))?;
    let _ = server.join();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let native_only = std::env::args().any(|a| a == "--native-only");

    // Phase 1: PJRT engine — AOT HLO artifacts from the JAX+Pallas layers.
    if native_only {
        println!("(--native-only: skipping the PJRT phase)");
    } else {
        let r = run_server_and_drive(
            rana::coordinator::ServerConfig {
                model: "llama-sim".into(),
                port: 7071,
                max_batch: 4,
                engine: "pjrt".into(),
                ..rana::coordinator::ServerConfig::default()
            },
            "PJRT engine (AOT jax+pallas artifacts)",
        );
        if let Err(e) = r {
            println!("PJRT phase skipped (artifacts unavailable?): {e:#}");
        }
    }

    // Phase 2: native engine with the runtime-budget controller — one
    // engine, calibrated once, serving dense/0.2/0.35/0.5 via its budget
    // schedule.
    run_server_and_drive(
        rana::coordinator::ServerConfig {
            model: "llama-sim".into(),
            port: 7072,
            max_batch: 4,
            adaptive_budget: true,
            calib_fit: 512,
            ..rana::coordinator::ServerConfig::default()
        },
        "native engine (runtime budget controller, tiers dense/0.2/0.35/0.5)",
    )?;
    println!(
        "\nserve_e2e OK — all three layers composed (L1 pallas → L2 jax → HLO → L3 rust)."
    );
    Ok(())
}
