//! Quickstart: load a trained model, apply a RaNA adapter at ~30 % FLOP
//! compression, and compare dense vs adapted behaviour on real text.
//!
//!     cargo run --release --example quickstart
//!
//! Requires `make artifacts` (trains the simulated models).

use std::sync::Arc;

use rana::adapters::calibrate::{self, CalibOptions, Method};
use rana::adapters::AdaptedModel;

fn main() -> anyhow::Result<()> {
    // 1. Load the trained llama-sim model (SwiGLU decoder, byte-level).
    let model = Arc::new(rana::model::Model::load(&rana::model::model_dir("llama-sim"))?);
    println!(
        "loaded {}: {} params, {} layers",
        model.cfg.name,
        model.cfg.n_params(),
        model.cfg.n_layers
    );

    // 2. Collect calibration hidden states (the paper's X, Eqn. 7).
    let corpus = rana::data::generate_corpus(400_000, 40_000);
    let calib = calibrate::collect(
        &model,
        &corpus.train,
        &CalibOptions { n_fit: 1024, n_eval: 128, window: 128, seed: 7 },
    );

    // 3. Adapt with RaNA at a 30 % total-FLOP compression target.
    let (rana_model, report) =
        calibrate::adapt(Arc::clone(&model), &calib, Method::Rana, 0.30, 512, 7);
    println!(
        "RaNA adapted: total compression {:.1}% (mlp {:.1}%, qkv {:.1}%)",
        report.total_compression * 100.0,
        report.mlp_compression * 100.0,
        report.qkv_compression * 100.0
    );
    for (l, lr) in report.layers.iter().enumerate() {
        println!(
            "  layer {l}: mlp reconstruction err {:.2}%, qkv err {:.2}%",
            lr.mlp_err * 100.0,
            lr.qkv_err * 100.0
        );
    }

    // 4. Compare perplexity and generations.
    let dense = AdaptedModel::unadapted(Arc::clone(&model));
    let ppl_dense = rana::eval::perplexity(&dense, &corpus.heldout, 8_000, 256);
    let ppl_rana = rana::eval::perplexity(&rana_model, &corpus.heldout, 8_000, 256);
    println!("perplexity: dense {ppl_dense:.3} → RaNA {ppl_rana:.3}");

    let prompt = "about xtatu : the ";
    println!("dense  : {}", rana::eval::greedy_decode(&dense, prompt, 48));
    println!("RaNA   : {}", rana::eval::greedy_decode(&rana_model, prompt, 48));
    Ok(())
}
