//! Compression sweep: the accuracy/perplexity-vs-FLOPs trade-off on one
//! model, for any subset of methods — the workhorse behind Figs. 1a/5.
//!
//!     cargo run --release --example compression_sweep -- \
//!         --model llama-sim --methods rana,cats --rates 0.15,0.3,0.45
//!
//! Requires `make artifacts`.

use rana::adapters::calibrate::Method;
use rana::bench::experiments::{Opts, Workbench};
use rana::bench::harness::Table;
use rana::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let model = args.get_str("model", "llama-sim");
    let methods: Vec<Method> = args
        .get_str("methods", "rana,cats")
        .split(',')
        .map(Method::parse)
        .collect::<anyhow::Result<_>>()?;
    let rates: Vec<f64> = args
        .get_str("rates", "0.15,0.3,0.45")
        .split(',')
        .filter_map(|s| s.parse().ok())
        .collect();
    let opts = Opts {
        ppl_tokens: args.get_usize("ppl-tokens", 12_000),
        items: args.get_usize("items", 40),
        ..Opts::default()
    };

    let wb = Workbench::load(&model, opts)?;
    let mut t = Table::new(&["Method", "Target", "Achieved", "Avg Acc", "PPL"]);
    let dense = wb.eval_row(&wb.dense(), None);
    t.row(vec![
        "dense".into(),
        "-".into(),
        "0.0%".into(),
        format!("{:.2}%", dense.avg * 100.0),
        format!("{:.3}", dense.ppl),
    ]);
    for &method in &methods {
        for &rate in &rates {
            let (m, rep) = wb.adapt(method, rate);
            let row = wb.eval_row(&m, Some(&rep));
            t.row(vec![
                method.label().into(),
                format!("{:.0}%", rate * 100.0),
                format!("{:.1}%", rep.total_compression * 100.0),
                format!("{:.2}%", row.avg * 100.0),
                format!("{:.3}", row.ppl),
            ]);
            t.print_last();
        }
    }
    println!("\nfull table:");
    t.print();
    Ok(())
}
