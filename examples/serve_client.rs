//! Example client for the typed serving protocol: drives generate,
//! streaming, speculative (`spec_k`) generation, cancel, stats (including
//! windowed reset), and trace against a running `rana serve`, asserting
//! the response schema — timing blocks included — along the way.
//! Used by the CI serving smoke step (`--spec` additionally asserts the
//! draft/accepted counters move when the server runs with `--spec-k`).
//!
//!     rana serve --model llama-sim --adaptive-budget --spec-k 3 --port 7070 &
//!     cargo run --release --example serve_client -- --port 7070 [--spec] [--shutdown]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use rana::util::cli::Args;
use rana::util::json::Json;

struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: &str) -> anyhow::Result<Self> {
        for _ in 0..600 {
            if let Ok(stream) = TcpStream::connect(addr) {
                let writer = stream.try_clone()?;
                return Ok(Self { writer, reader: BufReader::new(stream) });
            }
            std::thread::sleep(Duration::from_millis(100));
        }
        anyhow::bail!("server at {addr} never came up")
    }

    fn send(&mut self, req: &Json) -> anyhow::Result<()> {
        writeln!(self.writer, "{req}")?;
        Ok(())
    }

    fn recv(&mut self) -> anyhow::Result<Json> {
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        anyhow::ensure!(!line.is_empty(), "server closed the connection");
        Ok(Json::parse(line.trim())?)
    }

    fn call(&mut self, req: &Json) -> anyhow::Result<Json> {
        self.send(req)?;
        self.recv()
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let addr = format!("127.0.0.1:{}", args.get_usize("port", 7070));
    let mut c = Client::connect(&addr)?;

    // 1. Plain generate (greedy).
    let r = c.call(&Json::obj(vec![
        ("op", Json::str("generate")),
        ("id", Json::str("g1")),
        ("prompt", Json::str("the dax ")),
        ("tokens", Json::Num(12.0)),
    ]))?;
    assert_eq!(r.get_str("id")?, "g1");
    assert!(r.get_str("text")?.starts_with("the dax "), "echoed prompt prefix: {r}");
    assert_eq!(r.get_str("finish_reason")?, "length");
    assert!(r.get_f64("budget").is_ok());
    let timing = r.get("timing")?;
    for key in ["queue_us", "ttft_us", "itl_mean_us", "total_us", "tokens"] {
        anyhow::ensure!(timing.get(key).is_ok(), "timing block missing {key}: {r}");
    }
    anyhow::ensure!(
        timing.get_f64("ttft_us")? <= timing.get_f64("total_us")?,
        "TTFT must not exceed total: {timing}"
    );
    println!(
        "generate ok: {} tokens at budget {} (ttft {} µs)",
        r.get_usize("tokens")?,
        r.get_f64("budget")?,
        timing.get_f64("ttft_us")?,
    );

    // 2. Sampled generate with a budget override.
    let r = c.call(&Json::obj(vec![
        ("op", Json::str("generate")),
        ("id", Json::str("g2")),
        ("prompt", Json::str("the fep ")),
        ("tokens", Json::Num(12.0)),
        ("temperature", Json::Num(0.8)),
        ("top_k", Json::Num(40.0)),
        ("seed", Json::Num(7.0)),
        ("budget", Json::Num(0.35)),
    ]))?;
    assert_eq!(r.get_f64("budget")?, 0.35, "budget override must be echoed: {r}");
    println!("sampled generate ok at budget 0.35");

    // 2b. Per-request speculative draft length (greedy: text must be the
    // server's exact non-speculative text — pinned by the bench; here we
    // assert the request round-trips and finishes normally).
    let r = c.call(&Json::obj(vec![
        ("op", Json::str("generate")),
        ("id", Json::str("g2b")),
        ("prompt", Json::str("the dax ")),
        ("tokens", Json::Num(12.0)),
        ("spec_k", Json::Num(2.0)),
    ]))?;
    assert_eq!(r.get_str("id")?, "g2b");
    assert_eq!(r.get_str("finish_reason")?, "length");
    println!("speculative generate ok (spec_k=2)");

    // 3. Streaming generate: token frames, then one done frame.
    c.send(&Json::obj(vec![
        ("op", Json::str("generate")),
        ("id", Json::str("g3")),
        ("prompt", Json::str("the lopa ")),
        ("tokens", Json::Num(8.0)),
        ("stream", Json::Bool(true)),
    ]))?;
    let mut deltas = String::new();
    let mut frames = 0usize;
    let done = loop {
        let f = c.recv()?;
        frames += 1;
        match f.get("event")?.as_str() {
            Some("token") => deltas.push_str(f.get_str("delta")?),
            Some("done") => break f,
            other => anyhow::bail!("unexpected frame event {other:?}: {f}"),
        }
    };
    // Frames must reassemble the final text exactly (tokens that decode to
    // nothing — BOS/padding on a random-init model — produce no frames).
    assert_eq!(format!("the lopa {deltas}"), done.get_str("text")?.to_string());
    anyhow::ensure!(
        done.get("timing")?.get("ttft_us").is_ok(),
        "stream done frame must carry a timing block: {done}"
    );
    println!("streaming ok: {frames} frames reassemble the text");

    // 4. Cancel an in-flight streaming generate from a second connection
    // (waits for a token frame as the in-flight signal; a model that
    // streams nothing visible degrades to a warning).
    c.send(&Json::obj(vec![
        ("op", Json::str("generate")),
        ("id", Json::str("g4")),
        ("prompt", Json::str("about ")),
        ("tokens", Json::Num(200.0)),
        ("stream", Json::Bool(true)),
    ]))?;
    let mut in_flight = false;
    let mut finished_early = None;
    loop {
        let f = c.recv()?;
        match f.get("event")?.as_str() {
            Some("token") => {
                in_flight = true;
                break;
            }
            Some("done") => {
                finished_early = Some(f);
                break;
            }
            other => anyhow::bail!("unexpected frame event {other:?}: {f}"),
        }
    }
    if let Some(done) = finished_early {
        println!(
            "warning: generate streamed no visible tokens ({done}); skipping the \
             mid-flight cancel check (covered deterministically by test_protocol.rs)"
        );
    } else if in_flight {
        let mut c2 = Client::connect(&addr)?;
        let cr = c2.call(&Json::obj(vec![
            ("op", Json::str("cancel")),
            ("target", Json::str("g4")),
        ]))?;
        println!("cancel response: {cr}");
        let done = loop {
            let f = c.recv()?;
            if f.get("event")?.as_str() == Some("done") {
                break f;
            }
        };
        assert_eq!(
            done.get_str("finish_reason")?,
            "cancelled",
            "cancelled mid-flight: {done}"
        );
        assert!(done.get_usize("tokens")? < 200);
        println!("cancel ok: finished after {} tokens", done.get_usize("tokens")?);
    }

    // 5. Structured errors keep the connection serving.
    let e = c.call(&Json::obj(vec![
        ("op", Json::str("generate")),
        ("prompt", Json::str("x")),
        ("tokens", Json::Num(0.0)),
    ]))?;
    assert_eq!(e.get("error")?.get_str("code")?, "invalid_request");
    let e = c.call(&Json::obj(vec![("op", Json::str("nope"))]))?;
    assert_eq!(e.get("error")?.get_str("code")?, "unknown_op");
    println!("validation ok: structured errors, connection still live");

    // 6. Stats: runtime-budget + speculation + latency/tracing metrics
    // present.
    let s = c.call(&Json::obj(vec![("op", Json::str("stats"))]))?;
    for key in [
        "budget_hist",
        "budget_switches",
        "effective_rank_frac",
        "rank_budget",
        "draft_tokens",
        "accepted_tokens",
        "spec_acceptance",
        "spec_rollbacks",
        "ttft_hist",
        "ttft_edges",
        "itl_hist",
        "itl_edges",
        "queue_wait_hist",
        "queue_wait_edges",
        "mean_ttft_us",
        "mean_itl_us",
        "p50_ttft_us",
        "p99_ttft_us",
        "phase_us",
    ] {
        anyhow::ensure!(s.get(key).is_ok(), "stats missing {key}: {s}");
    }
    anyhow::ensure!(
        s.get_f64("mean_ttft_us")? > 0.0,
        "generates above must have produced TTFT samples: {s}"
    );
    if args.get_flag("spec") {
        // Server-side speculation is on (`--spec-k`): the spec_k request
        // above (and the server default) must have proposed drafts.
        anyhow::ensure!(
            s.get_f64("draft_tokens")? > 0.0,
            "speculation enabled but no draft tokens were proposed: {s}"
        );
        anyhow::ensure!(
            s.get_f64("accepted_tokens")? <= s.get_f64("draft_tokens")?,
            "accepted tokens exceed proposals: {s}"
        );
    }
    println!("stats ok: {s}");

    // 7. Trace: the finished requests above are in the timeline ring.
    let t = c.call(&Json::obj(vec![
        ("op", Json::str("trace")),
        ("last", Json::Num(5.0)),
    ]))?;
    anyhow::ensure!(t.get_f64("count")? >= 1.0, "trace ring must hold timelines: {t}");
    let timelines = t
        .get("timelines")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("timelines must be an array: {t}"))?;
    for tl in timelines {
        anyhow::ensure!(tl.get("total_us").is_ok() && tl.get("events").is_ok());
    }
    println!("trace ok: {} timelines", timelines.len());

    // 8. stats reset closes the window: the next snapshot starts clean.
    let closing = c.call(&Json::obj(vec![
        ("op", Json::str("stats")),
        ("reset", Json::Bool(true)),
    ]))?;
    anyhow::ensure!(closing.get_f64("tokens_generated")? > 0.0, "closing window: {closing}");
    let fresh = c.call(&Json::obj(vec![("op", Json::str("stats"))]))?;
    anyhow::ensure!(
        fresh.get_f64("tokens_generated")? == 0.0,
        "reset must zero the token counter: {fresh}"
    );
    anyhow::ensure!(
        fresh.get_f64("mean_ttft_us")? == 0.0,
        "reset must zero the TTFT window: {fresh}"
    );
    println!("stats reset ok: window restarted");

    if args.get_flag("shutdown") {
        let r = c.call(&Json::obj(vec![("op", Json::str("shutdown"))]))?;
        anyhow::ensure!(r.get("ok")?.as_bool() == Some(true));
        println!("shutdown ok");
    }
    println!("serve_client OK — generate/stream/cancel/stats/trace all verified");
    Ok(())
}
